//! Figure 11 — K,V-cache memory: exact byte accounting vs sequence
//! length, MHA vs CHAI (paper: up to 21.4% saving on LLaMA-7B).
//!
//! Run:  cargo bench --bench bench_memory

mod common;

use chai::bench::Table;
use chai::config::Manifest;
use chai::kv::{cache_bytes, chai_saving_fraction, CacheKind};
use chai::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(dir) = common::require_artifacts(&args) else { return Ok(()) };
    let m = Manifest::load(&dir)?;

    let seqlens = [128usize, 256, 512, 1024, 2048];
    let mut table = Table::new(
        "Figure 11: K,V cache size vs sequence length",
        &["seq len", "MHA (KiB)", "CHAI (KiB)", "saving %"],
    );
    let mut rows = Vec::new();
    for &t in &seqlens {
        let mha = cache_bytes(CacheKind::Mha, &m, t);
        let chai = cache_bytes(CacheKind::Chai, &m, t);
        let saving = 100.0 * (1.0 - chai as f64 / mha as f64);
        table.row(vec![
            t.to_string(),
            format!("{}", mha / 1024),
            format!("{}", chai / 1024),
            format!("{saving:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("seq_len", Json::Num(t as f64)),
            ("mha_bytes", Json::Num(mha as f64)),
            ("chai_bytes", Json::Num(chai as f64)),
            ("saving_pct", Json::Num(saving)),
        ]));
    }
    table.print();

    // per-layer decomposition (where the saving comes from)
    let mut per_layer = Table::new(
        "Per-layer K-head counts (offline elbow, clusters.json)",
        &["layer", "heads H", "clusters k_l", "K-panel saving %"],
    );
    for (l, &k) in m.k_list.iter().enumerate() {
        per_layer.row(vec![
            l.to_string(),
            m.model.n_heads.to_string(),
            k.to_string(),
            format!("{:.0}", 100.0 * (1.0 - k as f64 / m.model.n_heads as f64)),
        ]);
    }
    per_layer.print();

    let total = 100.0 * chai_saving_fraction(&m);
    println!("\ntotal K,V saving: {total:.1}%  (paper: up to 21.4% on LLaMA-7B;");
    println!("saving is length-independent because both caches scale linearly in T)");

    common::write_results(
        "memory",
        Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("k_list", Json::from_usizes(&m.k_list)),
            ("total_saving_pct", Json::Num(total)),
        ]),
    );
    Ok(())
}
