//! Figure 11 — K,V-cache memory: exact byte accounting vs sequence
//! length, MHA vs CHAI (paper: up to 21.4% saving on LLaMA-7B).
//!
//! Run:  cargo bench --bench bench_memory

mod common;

use chai::bench::Table;
use chai::config::Manifest;
use chai::kv::paged::paged_cache_bytes;
use chai::kv::{cache_bytes, chai_saving_fraction, CacheKind};
use chai::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(dir) = common::require_artifacts(&args) else { return Ok(()) };
    let m = Manifest::load(&dir)?;

    let seqlens = [128usize, 256, 512, 1024, 2048];
    let mut table = Table::new(
        "Figure 11: K,V cache size vs sequence length",
        &["seq len", "MHA (KiB)", "CHAI (KiB)", "saving %"],
    );
    let mut rows = Vec::new();
    for &t in &seqlens {
        let mha = cache_bytes(CacheKind::Mha, &m, t);
        let chai = cache_bytes(CacheKind::Chai, &m, t);
        let saving = 100.0 * (1.0 - chai as f64 / mha as f64);
        table.row(vec![
            t.to_string(),
            format!("{}", mha / 1024),
            format!("{}", chai / 1024),
            format!("{saving:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("seq_len", Json::Num(t as f64)),
            ("mha_bytes", Json::Num(mha as f64)),
            ("chai_bytes", Json::Num(chai as f64)),
            ("saving_pct", Json::Num(saving)),
        ]));
    }
    table.print();

    // per-layer decomposition (where the saving comes from)
    let mut per_layer = Table::new(
        "Per-layer K-head counts (offline elbow, clusters.json)",
        &["layer", "heads H", "clusters k_l", "K-panel saving %"],
    );
    for (l, &k) in m.k_list.iter().enumerate() {
        per_layer.row(vec![
            l.to_string(),
            m.model.n_heads.to_string(),
            k.to_string(),
            format!("{:.0}", 100.0 * (1.0 - k as f64 / m.model.n_heads as f64)),
        ]);
    }
    per_layer.print();

    let total = 100.0 * chai_saving_fraction(&m);
    println!("\ntotal K,V saving: {total:.1}%  (paper: up to 21.4% on LLaMA-7B;");
    println!("saving is length-independent because both caches scale linearly in T)");

    // block-granular occupancy: the paged pool rounds up to whole blocks
    // (tiny overhead) where the legacy admission pads to whole buckets
    let block = 16usize;
    let mut paged_table = Table::new(
        "Paged occupancy (block = 16) vs contiguous exact bytes",
        &["seq len", "CHAI exact KiB", "CHAI paged KiB", "round-up %", "paged saving vs MHA %"],
    );
    let mut paged_rows = Vec::new();
    for &t in &seqlens {
        let exact = cache_bytes(CacheKind::Chai, &m, t);
        let paged = paged_cache_bytes(CacheKind::Chai, &m, t, block);
        let paged_mha = paged_cache_bytes(CacheKind::Mha, &m, t, block);
        let overhead = 100.0 * (paged as f64 / exact as f64 - 1.0);
        let saving = 100.0 * (1.0 - paged as f64 / paged_mha as f64);
        paged_table.row(vec![
            t.to_string(),
            format!("{}", exact / 1024),
            format!("{}", paged / 1024),
            format!("{overhead:.2}"),
            format!("{saving:.1}"),
        ]);
        paged_rows.push(Json::obj(vec![
            ("seq_len", Json::Num(t as f64)),
            ("chai_exact_bytes", Json::Num(exact as f64)),
            ("chai_paged_bytes", Json::Num(paged as f64)),
            ("mha_paged_bytes", Json::Num(paged_mha as f64)),
            ("paged_saving_pct", Json::Num(saving)),
        ]));
    }
    paged_table.print();

    common::write_results(
        "memory",
        Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("paged_rows", Json::Arr(paged_rows)),
            ("block_size", Json::Num(block as f64)),
            ("k_list", Json::from_usizes(&m.k_list)),
            ("total_saving_pct", Json::Num(total)),
        ]),
    );
    Ok(())
}
