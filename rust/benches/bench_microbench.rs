//! Microbenchmarks for the perf pass (EXPERIMENTS.md §Perf): per-artifact
//! execution latency, host<->device transfer cost, clustering cost, and
//! the engine-step breakdown. These locate the bottleneck before each
//! optimization iteration.
//!
//! Run:  cargo bench --bench bench_microbench [-- --iters 10]
//!       cargo bench --bench bench_microbench -- --backend ref   # no artifacts needed

mod common;

use chai::bench::{fmt_ms, Table};
use chai::engine::Engine;
use chai::model::tokenizer;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::json::Json;
use chai::util::stats::{median, time_ms};

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(cfg) = common::serving_config(&args) else { return Ok(()) };
    let engine = Engine::load(cfg)?;
    let m = engine.manifest().clone();
    let iters = args.usize("iters", 6)?;
    let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);

    // ---- artifact execution latency --------------------------------------
    let mut table = Table::new("Per-artifact execution latency", &["artifact", "median ms"]);
    let mut rows = Vec::new();
    let mut bench_artifact = |name: &str, ins: &dyn Fn() -> Vec<Tensor>| -> anyhow::Result<f64> {
        engine.rt.warmup(&[name])?;
        let tensors = ins();
        let ms = median(&time_ms(2, iters, || {
            let refs: Vec<In> = tensors.iter().map(In::Host).collect();
            engine.rt.run(name, &refs).unwrap();
        }));
        Ok(ms)
    };

    let probe_ms = bench_artifact("probe_mha", &|| {
        vec![Tensor::zeros_i32(&[m.probe_bucket]), Tensor::scalar_i32(5)]
    })?;
    table.row(vec!["probe_mha".into(), fmt_ms(probe_ms)]);
    rows.push(Json::obj(vec![("name", Json::Str("probe_mha".into())), ("ms", Json::Num(probe_ms))]));

    let lp = m.logprob_bucket;
    let lg_ms = bench_artifact("logprob_mha", &|| {
        vec![Tensor::zeros_i32(&[lp]), Tensor::scalar_i32(24)]
    })?;
    table.row(vec!["logprob_mha".into(), fmt_ms(lg_ms)]);
    rows.push(Json::obj(vec![("name", Json::Str("logprob_mha".into())), ("ms", Json::Num(lg_ms))]));

    for &t in &m.decode_buckets.clone() {
        let name = format!("decode_mha_t{t}");
        let ms = bench_artifact(&name, &|| {
            vec![
                Tensor::scalar_i32(1),
                Tensor::scalar_i32((t - 2) as i32),
                Tensor::zeros_f32(&[l, h, t, dh]),
                Tensor::zeros_f32(&[l, h, t, dh]),
            ]
        })?;
        table.row(vec![name.clone(), fmt_ms(ms)]);
        rows.push(Json::obj(vec![("name", Json::Str(name)), ("ms", Json::Num(ms))]));
    }
    table.print();

    // ---- transfer cost (PJRT-only: host->device upload) ------------------
    if engine.backend_name() == "xla" {
        // a bare client is enough to time uploads — no second Runtime
        // (and no duplicate device-resident weights)
        let client = xla::PjRtClient::cpu()?;
        let mut xfer = Table::new("Host->device upload cost", &["tensor", "MiB", "median ms"]);
        for &t in &[128usize, 2048] {
            let kc = Tensor::zeros_f32(&[l, h, t, dh]);
            let ms = median(&time_ms(2, iters, || {
                chai::runtime::upload(&client, &kc).unwrap();
            }));
            xfer.row(vec![
                format!("kv cache T={t}"),
                format!("{:.1}", kc.nbytes() as f64 / 1048576.0),
                fmt_ms(ms),
            ]);
        }
        xfer.print();
    }

    // ---- clustering cost ---------------------------------------------------
    let toks = tokenizer::encode("the color of tom is red .", true, false);
    let cluster_ms = median(&time_ms(1, iters, || {
        engine.online_membership(&toks).unwrap();
    }));
    let mut cl = Table::new("CHAI online overhead (probe + k-means)", &["stage", "median ms"]);
    cl.row(vec!["probe+cluster total".into(), fmt_ms(cluster_ms)]);
    cl.row(vec!["probe exec only".into(), fmt_ms(probe_ms)]);
    cl.row(vec!["k-means only (approx)".into(), fmt_ms(cluster_ms - probe_ms)]);
    cl.print();

    common::write_results(
        "microbench",
        Json::obj(vec![
            ("artifacts", Json::Arr(rows)),
            ("online_membership_ms", Json::Num(cluster_ms)),
        ]),
    );
    Ok(())
}
