//! Microbenchmarks for the perf pass (EXPERIMENTS.md §Perf): per-artifact
//! execution latency, host<->device transfer cost, clustering cost, and
//! the engine-step breakdown. These locate the bottleneck before each
//! optimization iteration.
//!
//! Run:  cargo bench --bench bench_microbench [-- --iters 10]
//!       cargo bench --bench bench_microbench -- --backend ref   # no artifacts needed

mod common;

use chai::bench::{fmt_ms, Table};
use chai::engine::Engine;
use chai::model::tokenizer;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::json::Json;
use chai::util::stats::{median, time_ms};

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(cfg) = common::serving_config(&args) else { return Ok(()) };
    let engine = Engine::load(cfg)?;
    let m = engine.manifest().clone();
    let iters = args.usize("iters", 6)?;
    let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);

    // ---- artifact execution latency --------------------------------------
    let mut table = Table::new("Per-artifact execution latency", &["artifact", "median ms"]);
    let mut rows = Vec::new();
    let mut bench_artifact = |name: &str, ins: &dyn Fn() -> Vec<Tensor>| -> anyhow::Result<f64> {
        engine.rt.warmup(&[name])?;
        let tensors = ins();
        let ms = median(&time_ms(2, iters, || {
            let refs: Vec<In> = tensors.iter().map(In::Host).collect();
            engine.rt.run(name, &refs).unwrap();
        }));
        Ok(ms)
    };

    let probe_ms = bench_artifact("probe_mha", &|| {
        vec![Tensor::zeros_i32(&[m.probe_bucket]), Tensor::scalar_i32(5)]
    })?;
    table.row(vec!["probe_mha".into(), fmt_ms(probe_ms)]);
    rows.push(Json::obj(vec![("name", Json::Str("probe_mha".into())), ("ms", Json::Num(probe_ms))]));

    let lp = m.logprob_bucket;
    let lg_ms = bench_artifact("logprob_mha", &|| {
        vec![Tensor::zeros_i32(&[lp]), Tensor::scalar_i32(24)]
    })?;
    table.row(vec!["logprob_mha".into(), fmt_ms(lg_ms)]);
    rows.push(Json::obj(vec![("name", Json::Str("logprob_mha".into())), ("ms", Json::Num(lg_ms))]));

    for &t in &m.decode_buckets.clone() {
        let name = format!("decode_mha_t{t}");
        let ms = bench_artifact(&name, &|| {
            vec![
                Tensor::scalar_i32(1),
                Tensor::scalar_i32((t - 2) as i32),
                Tensor::zeros_f32(&[l, h, t, dh]),
                Tensor::zeros_f32(&[l, h, t, dh]),
            ]
        })?;
        table.row(vec![name.clone(), fmt_ms(ms)]);
        rows.push(Json::obj(vec![("name", Json::Str(name)), ("ms", Json::Num(ms))]));
    }
    table.print();

    // ---- transfer cost (PJRT-only: host->device upload) ------------------
    if engine.backend_name() == "xla" {
        // a bare client is enough to time uploads — no second Runtime
        // (and no duplicate device-resident weights)
        let client = xla::PjRtClient::cpu()?;
        let mut xfer = Table::new("Host->device upload cost", &["tensor", "MiB", "median ms"]);
        for &t in &[128usize, 2048] {
            let kc = Tensor::zeros_f32(&[l, h, t, dh]);
            let ms = median(&time_ms(2, iters, || {
                chai::runtime::upload(&client, &kc).unwrap();
            }));
            xfer.row(vec![
                format!("kv cache T={t}"),
                format!("{:.1}", kc.nbytes() as f64 / 1048576.0),
                fmt_ms(ms),
            ]);
        }
        xfer.print();
    }

    // ---- clustering cost ---------------------------------------------------
    let toks = tokenizer::encode("the color of tom is red .", true, false);
    let cluster_ms = median(&time_ms(1, iters, || {
        engine.online_membership(&toks).unwrap();
    }));
    let mut cl = Table::new("CHAI online overhead (probe + k-means)", &["stage", "median ms"]);
    cl.row(vec!["probe+cluster total".into(), fmt_ms(cluster_ms)]);
    cl.row(vec!["probe exec only".into(), fmt_ms(probe_ms)]);
    cl.row(vec!["k-means only (approx)".into(), fmt_ms(cluster_ms - probe_ms)]);
    cl.print();

    // ---- paged attention kernels: block-wise slab hoist (before/after) ----
    // The serving decode path reads K,V straight out of pool slabs; the
    // hoisted kernels look the slab up once per block and stop at the
    // causal bound, where the original walked `blocks[kj/B]` per key and
    // accumulated the softmaxed-to-zero masked tail. Same numbers
    // (asserted bitwise), different constant factor.
    let (kh, kdh, kb, klen, ktq) = (8usize, 32usize, 16usize, 512usize, 128usize);
    let q_offset = klen - ktq;
    let slab_len = 2 * kh * kb * kdh;
    let v_base = kh * kb * kdh;
    // deterministic LCG fill — no RNG dependency in benches
    let mut state = 0x2545f4914f6cdd1du64;
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    };
    let slabs_owned: Vec<Vec<f32>> = (0..klen / kb)
        .map(|_| (0..slab_len).map(|_| next()).collect())
        .collect();
    let slabs: Vec<&[f32]> = slabs_owned.iter().map(|s| s.as_slice()).collect();
    let q: Vec<f32> = (0..kh * ktq * kdh).map(|_| next()).collect();

    let hoisted = chai::runtime::refkernels::paged_mha_attention(
        &q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen,
    );
    let naive = naive_paged_mha(&q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen);
    assert_eq!(
        hoisted, naive,
        "hoisted paged kernels must be bit-identical to the per-key-lookup original"
    );

    let after_ms = median(&time_ms(1, iters, || {
        chai::runtime::refkernels::paged_mha_attention(
            &q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen,
        );
    }));
    let before_ms = median(&time_ms(1, iters, || {
        naive_paged_mha(&q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen);
    }));
    let mut pk = Table::new(
        "Paged attention kernel (scores+AV, h=8 dh=32 B=16 len=512 tq=128)",
        &["kernel", "median ms"],
    );
    pk.row(vec!["per-key slab lookup + full AV walk (before)".into(), fmt_ms(before_ms)]);
    pk.row(vec!["block-wise hoist + causal-bounded AV (after)".into(), fmt_ms(after_ms)]);
    pk.row(vec!["speedup".into(), format!("{:.2}x", before_ms / after_ms.max(1e-9))]);
    pk.print();

    // ---- rope: per-position sin/cos hoist (before/after) -------------------
    // The old body recomputed `angle.sin()/.cos()` for every head group;
    // the hoisted kernel builds the (position, channel) table once and
    // reuses it across all g groups. Same calls per unique angle, so the
    // result is bitwise-pinned (asserted).
    let (rg, rt, rdh) = (32usize, 256usize, 64usize);
    let rope_positions: Vec<usize> = (100..100 + rt).collect();
    let rope_x: Vec<f32> = (0..rg * rt * rdh).map(|_| next()).collect();
    let mut x_old = rope_x.clone();
    let mut x_new = rope_x.clone();
    naive_rope(&mut x_old, &rope_positions, rg, rt, rdh, 10000.0);
    chai::runtime::refkernels::rope(&mut x_new, &rope_positions, rg, rt, rdh, 10000.0);
    assert_eq!(x_old, x_new, "hoisted rope must be bit-identical to the per-group original");
    let rope_before_ms = median(&time_ms(1, iters, || {
        let mut x = rope_x.clone();
        naive_rope(&mut x, &rope_positions, rg, rt, rdh, 10000.0);
    }));
    let rope_after_ms = median(&time_ms(1, iters, || {
        let mut x = rope_x.clone();
        chai::runtime::refkernels::rope(&mut x, &rope_positions, rg, rt, rdh, 10000.0);
    }));
    let mut rp = Table::new("Rope kernel (g=32 t=256 dh=64)", &["kernel", "median ms"]);
    rp.row(vec!["sin/cos per head group (before)".into(), fmt_ms(rope_before_ms)]);
    rp.row(vec!["sin/cos hoisted per position (after)".into(), fmt_ms(rope_after_ms)]);
    rp.row(vec!["speedup".into(), format!("{:.2}x", rope_before_ms / rope_after_ms.max(1e-9))]);
    rp.print();

    // ---- kernel scaling across pool sizes ----------------------------------
    // Installs an explicit pool per row (replacing the engine's) and times
    // the two hottest kernels. Outputs are asserted bitwise-identical to
    // the 1-thread run at every size — the partitioning invariant the
    // parallel test suite pins down, visible here as a scaling table.
    let (sm, sk, sn) = (128usize, 512usize, 512usize);
    let sa: Vec<f32> = (0..sm * sk).map(|_| next()).collect();
    let sb: Vec<f32> = (0..sk * sn).map(|_| next()).collect();
    let mut scal = Table::new(
        "Kernel scaling (matmul 128x512x512; paged attn h=8 dh=32 len=512 tq=128)",
        &["threads", "matmul ms", "paged attn ms"],
    );
    let mut scaling_rows = Vec::new();
    let mut base: Option<(Vec<f32>, Vec<f32>)> = None;
    for &threads in [1usize, 2, 4].iter() {
        if threads > 1 && threads > chai::runtime::pool::allowed_cpu_count() {
            continue;
        }
        let p = std::sync::Arc::new(chai::runtime::pool::Pool::new(threads, false));
        chai::runtime::pool::install(&p);
        let mm = chai::runtime::refkernels::matmul(&sa, &sb, sm, sk, sn);
        let at = chai::runtime::refkernels::paged_mha_attention(
            &q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen,
        );
        match &base {
            None => base = Some((mm, at)),
            Some((bmm, bat)) => {
                assert_eq!(bmm, &mm, "matmul must be pool-size invariant");
                assert_eq!(bat, &at, "paged attention must be pool-size invariant");
            }
        }
        let mm_ms = median(&time_ms(1, iters, || {
            chai::runtime::refkernels::matmul(&sa, &sb, sm, sk, sn);
        }));
        let at_ms = median(&time_ms(1, iters, || {
            chai::runtime::refkernels::paged_mha_attention(
                &q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen,
            );
        }));
        scal.row(vec![format!("{threads}"), fmt_ms(mm_ms), fmt_ms(at_ms)]);
        scaling_rows.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("matmul_ms", Json::Num(mm_ms)),
            ("paged_attn_ms", Json::Num(at_ms)),
        ]));
    }
    scal.print();

    common::write_results(
        "microbench",
        Json::obj(vec![
            ("artifacts", Json::Arr(rows)),
            ("online_membership_ms", Json::Num(cluster_ms)),
            ("paged_kernel_before_ms", Json::Num(before_ms)),
            ("paged_kernel_after_ms", Json::Num(after_ms)),
            ("rope_before_ms", Json::Num(rope_before_ms)),
            ("rope_after_ms", Json::Num(rope_after_ms)),
            ("scaling", Json::Arr(scaling_rows)),
        ]),
    );
    Ok(())
}

/// The pre-hoist rope body, kept verbatim as the microbench baseline:
/// `angle.sin()/.cos()` recomputed inside the per-head-group loop, i.e.
/// `g`× per (position, channel) pair.
fn naive_rope(x: &mut [f32], positions: &[usize], g: usize, t: usize, dh: usize, theta: f32) {
    assert_eq!(x.len(), g * t * dh, "x shape");
    assert_eq!(positions.len(), t, "positions shape");
    assert_eq!(dh % 2, 0, "head_dim must be even for rope");
    let half = dh / 2;
    // frequencies depend only on the channel — hoist out of the hot loop
    let freqs: Vec<f32> =
        (0..half).map(|i| theta.powf(-(i as f32) / half as f32)).collect();
    for gi in 0..g {
        for ti in 0..t {
            let row = &mut x[(gi * t + ti) * dh..(gi * t + ti) * dh + dh];
            let pos = positions[ti] as f32;
            for (i, &freq) in freqs.iter().enumerate() {
                let angle = pos * freq;
                let (sin, cos) = (angle.sin(), angle.cos());
                let (x1, x2) = (row[i], row[half + i]);
                row[i] = x1 * cos - x2 * sin;
                row[half + i] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// The pre-hoist paged MHA kernel, kept verbatim as the microbench
/// baseline: slab lookup per key (`blocks[kj / B]` inside the hot
/// loop), masked tail scored at -1e9, and the AV pass walking every key
/// in `[0, len)` including the masked entries that softmaxed to 0.0.
#[allow(clippy::too_many_arguments)]
fn naive_paged_mha(
    q: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    v_base: usize,
    h: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
) -> Vec<f32> {
    let scale = (dh as f32).sqrt();
    let mut probs = vec![0.0f32; h * tq * len];
    for gi in 0..h {
        for qi in 0..tq {
            let qrow = &q[(gi * tq + qi) * dh..(gi * tq + qi) * dh + dh];
            let orow = &mut probs[(gi * tq + qi) * len..(gi * tq + qi) * len + len];
            for (kj, slot) in orow.iter_mut().enumerate() {
                if kj > q_offset + qi {
                    *slot = -1e9;
                    continue;
                }
                let slab = blocks[kj / block_size];
                let base = k_base + (gi * block_size + kj % block_size) * dh;
                let krow = &slab[base..base + dh];
                let mut acc = 0.0f32;
                for d in 0..dh {
                    acc += qrow[d] * krow[d];
                }
                *slot = acc / scale;
            }
            let kmax = (q_offset + qi + 1).min(len);
            let mx = orow[..kmax].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in orow[..kmax].iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in orow[..kmax].iter_mut() {
                *x /= sum;
            }
            for x in orow[kmax..].iter_mut() {
                *x = ((*x) - mx).exp(); // underflows to exactly 0.0
            }
        }
    }
    let mut out = vec![0.0f32; h * tq * dh];
    for gi in 0..h {
        for qi in 0..tq {
            let prow = &probs[(gi * tq + qi) * len..(gi * tq + qi) * len + len];
            let orow = &mut out[(gi * tq + qi) * dh..(gi * tq + qi) * dh + dh];
            for (kj, &p) in prow.iter().enumerate() {
                let slab = blocks[kj / block_size];
                let base = v_base + (gi * block_size + kj % block_size) * dh;
                let vrow = &slab[base..base + dh];
                for d in 0..dh {
                    orow[d] += p * vrow[d];
                }
            }
        }
    }
    out
}
