//! Microbenchmarks for the perf pass (EXPERIMENTS.md §Perf): per-artifact
//! execution latency, host<->device transfer cost, clustering cost, and
//! the engine-step breakdown. These locate the bottleneck before each
//! optimization iteration.
//!
//! Run:  cargo bench --bench bench_microbench [-- --iters 10]
//!       cargo bench --bench bench_microbench -- --backend ref   # no artifacts needed

mod common;

use chai::bench::{fmt_ms, Table};
use chai::engine::Engine;
use chai::model::tokenizer;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::json::Json;
use chai::util::stats::{median, time_ms};

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(cfg) = common::serving_config(&args) else { return Ok(()) };
    let engine = Engine::load(cfg)?;
    let m = engine.manifest().clone();
    let iters = args.usize("iters", 6)?;
    let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);

    // ---- artifact execution latency --------------------------------------
    let mut table = Table::new("Per-artifact execution latency", &["artifact", "median ms"]);
    let mut rows = Vec::new();
    let mut bench_artifact = |name: &str, ins: &dyn Fn() -> Vec<Tensor>| -> anyhow::Result<f64> {
        engine.rt.warmup(&[name])?;
        let tensors = ins();
        let ms = median(&time_ms(2, iters, || {
            let refs: Vec<In> = tensors.iter().map(In::Host).collect();
            engine.rt.run(name, &refs).unwrap();
        }));
        Ok(ms)
    };

    let probe_ms = bench_artifact("probe_mha", &|| {
        vec![Tensor::zeros_i32(&[m.probe_bucket]), Tensor::scalar_i32(5)]
    })?;
    table.row(vec!["probe_mha".into(), fmt_ms(probe_ms)]);
    rows.push(Json::obj(vec![("name", Json::Str("probe_mha".into())), ("ms", Json::Num(probe_ms))]));

    let lp = m.logprob_bucket;
    let lg_ms = bench_artifact("logprob_mha", &|| {
        vec![Tensor::zeros_i32(&[lp]), Tensor::scalar_i32(24)]
    })?;
    table.row(vec!["logprob_mha".into(), fmt_ms(lg_ms)]);
    rows.push(Json::obj(vec![("name", Json::Str("logprob_mha".into())), ("ms", Json::Num(lg_ms))]));

    for &t in &m.decode_buckets.clone() {
        let name = format!("decode_mha_t{t}");
        let ms = bench_artifact(&name, &|| {
            vec![
                Tensor::scalar_i32(1),
                Tensor::scalar_i32((t - 2) as i32),
                Tensor::zeros_f32(&[l, h, t, dh]),
                Tensor::zeros_f32(&[l, h, t, dh]),
            ]
        })?;
        table.row(vec![name.clone(), fmt_ms(ms)]);
        rows.push(Json::obj(vec![("name", Json::Str(name)), ("ms", Json::Num(ms))]));
    }
    table.print();

    // ---- transfer cost (PJRT-only: host->device upload) ------------------
    if engine.backend_name() == "xla" {
        // a bare client is enough to time uploads — no second Runtime
        // (and no duplicate device-resident weights)
        let client = xla::PjRtClient::cpu()?;
        let mut xfer = Table::new("Host->device upload cost", &["tensor", "MiB", "median ms"]);
        for &t in &[128usize, 2048] {
            let kc = Tensor::zeros_f32(&[l, h, t, dh]);
            let ms = median(&time_ms(2, iters, || {
                chai::runtime::upload(&client, &kc).unwrap();
            }));
            xfer.row(vec![
                format!("kv cache T={t}"),
                format!("{:.1}", kc.nbytes() as f64 / 1048576.0),
                fmt_ms(ms),
            ]);
        }
        xfer.print();
    }

    // ---- clustering cost ---------------------------------------------------
    let toks = tokenizer::encode("the color of tom is red .", true, false);
    let cluster_ms = median(&time_ms(1, iters, || {
        engine.online_membership(&toks).unwrap();
    }));
    let mut cl = Table::new("CHAI online overhead (probe + k-means)", &["stage", "median ms"]);
    cl.row(vec!["probe+cluster total".into(), fmt_ms(cluster_ms)]);
    cl.row(vec!["probe exec only".into(), fmt_ms(probe_ms)]);
    cl.row(vec!["k-means only (approx)".into(), fmt_ms(cluster_ms - probe_ms)]);
    cl.print();

    // ---- paged attention kernels: block-wise slab hoist (before/after) ----
    // The serving decode path reads K,V straight out of pool slabs; the
    // hoisted kernels look the slab up once per block and stop at the
    // causal bound, where the original walked `blocks[kj/B]` per key and
    // accumulated the softmaxed-to-zero masked tail. Same numbers
    // (asserted bitwise), different constant factor.
    let (kh, kdh, kb, klen, ktq) = (8usize, 32usize, 16usize, 512usize, 128usize);
    let q_offset = klen - ktq;
    let slab_len = 2 * kh * kb * kdh;
    let v_base = kh * kb * kdh;
    // deterministic LCG fill — no RNG dependency in benches
    let mut state = 0x2545f4914f6cdd1du64;
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    };
    let slabs_owned: Vec<Vec<f32>> = (0..klen / kb)
        .map(|_| (0..slab_len).map(|_| next()).collect())
        .collect();
    let slabs: Vec<&[f32]> = slabs_owned.iter().map(|s| s.as_slice()).collect();
    let q: Vec<f32> = (0..kh * ktq * kdh).map(|_| next()).collect();

    let hoisted = chai::runtime::refkernels::paged_mha_attention(
        &q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen,
    );
    let naive = naive_paged_mha(&q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen);
    assert_eq!(
        hoisted, naive,
        "hoisted paged kernels must be bit-identical to the per-key-lookup original"
    );

    let after_ms = median(&time_ms(1, iters, || {
        chai::runtime::refkernels::paged_mha_attention(
            &q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen,
        );
    }));
    let before_ms = median(&time_ms(1, iters, || {
        naive_paged_mha(&q, &slabs, 0, v_base, kh, ktq, kdh, kb, q_offset, klen);
    }));
    let mut pk = Table::new(
        "Paged attention kernel (scores+AV, h=8 dh=32 B=16 len=512 tq=128)",
        &["kernel", "median ms"],
    );
    pk.row(vec!["per-key slab lookup + full AV walk (before)".into(), fmt_ms(before_ms)]);
    pk.row(vec!["block-wise hoist + causal-bounded AV (after)".into(), fmt_ms(after_ms)]);
    pk.row(vec!["speedup".into(), format!("{:.2}x", before_ms / after_ms.max(1e-9))]);
    pk.print();

    common::write_results(
        "microbench",
        Json::obj(vec![
            ("artifacts", Json::Arr(rows)),
            ("online_membership_ms", Json::Num(cluster_ms)),
            ("paged_kernel_before_ms", Json::Num(before_ms)),
            ("paged_kernel_after_ms", Json::Num(after_ms)),
        ]),
    );
    Ok(())
}

/// The pre-hoist paged MHA kernel, kept verbatim as the microbench
/// baseline: slab lookup per key (`blocks[kj / B]` inside the hot
/// loop), masked tail scored at -1e9, and the AV pass walking every key
/// in `[0, len)` including the masked entries that softmaxed to 0.0.
#[allow(clippy::too_many_arguments)]
fn naive_paged_mha(
    q: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    v_base: usize,
    h: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
) -> Vec<f32> {
    let scale = (dh as f32).sqrt();
    let mut probs = vec![0.0f32; h * tq * len];
    for gi in 0..h {
        for qi in 0..tq {
            let qrow = &q[(gi * tq + qi) * dh..(gi * tq + qi) * dh + dh];
            let orow = &mut probs[(gi * tq + qi) * len..(gi * tq + qi) * len + len];
            for (kj, slot) in orow.iter_mut().enumerate() {
                if kj > q_offset + qi {
                    *slot = -1e9;
                    continue;
                }
                let slab = blocks[kj / block_size];
                let base = k_base + (gi * block_size + kj % block_size) * dh;
                let krow = &slab[base..base + dh];
                let mut acc = 0.0f32;
                for d in 0..dh {
                    acc += qrow[d] * krow[d];
                }
                *slot = acc / scale;
            }
            let kmax = (q_offset + qi + 1).min(len);
            let mx = orow[..kmax].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in orow[..kmax].iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in orow[..kmax].iter_mut() {
                *x /= sum;
            }
            for x in orow[kmax..].iter_mut() {
                *x = ((*x) - mx).exp(); // underflows to exactly 0.0
            }
        }
    }
    let mut out = vec![0.0f32; h * tq * dh];
    for gi in 0..h {
        for qi in 0..tq {
            let prow = &probs[(gi * tq + qi) * len..(gi * tq + qi) * len + len];
            let orow = &mut out[(gi * tq + qi) * dh..(gi * tq + qi) * dh + dh];
            for (kj, &p) in prow.iter().enumerate() {
                let slab = blocks[kj / block_size];
                let base = v_base + (gi * block_size + kj % block_size) * dh;
                let vrow = &slab[base..base + dh];
                for d in 0..dh {
                    orow[d] += p * vrow[d];
                }
            }
        }
    }
    out
}
