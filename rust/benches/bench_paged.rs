//! Paged vs contiguous K,V occupancy under a shared-system-prompt
//! workload (the RelayAttention-style scenario: many requests share a
//! long system prefix, diverge on user suffixes).
//!
//! Needs no artifacts: the accounting subsystem is driven directly with
//! a synthetic CHAI layout (real manifest dims are used when present).
//!
//! Run:  cargo bench --bench bench_paged
//!       [-- --requests 64 --system-prompts 4 --system-len 96
//!           --suffix-len 32 --decode 32 --window 8 --block-size 16]

mod common;

use chai::bench::Table;
use chai::config::Manifest;
use chai::kv::paged::{KvLayout, PagedKv};
use chai::kv::CacheKind;
use chai::util::json::Json;
use chai::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let n_requests = args.usize("requests", 64)?;
    let n_system = args.usize("system-prompts", 4)?;
    let system_len = args.usize("system-len", 96)?;
    let suffix_len = args.usize("suffix-len", 32)?;
    let decode = args.usize("decode", 32)?;
    let window = args.usize("window", 8)?;
    let block = args.usize("block-size", 16)?;

    // real CHAI geometry when artifacts exist, synthetic otherwise
    let dir = common::artifacts_dir(&args);
    let layout = if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir)?;
        KvLayout::from_manifest(&m, CacheKind::Chai)
    } else {
        KvLayout { n_layers: 6, n_heads: 16, head_dim: 32, k_heads: vec![6, 7, 8, 9, 10, 12] }
    };
    let fpt = layout.floats_per_token();
    let buckets = [32usize, 128, 512, 2048];

    let mut kv = PagedKv::new(block, 1 << 30);
    let mut rng = Rng::new(7);
    // system prompts: token streams disjoint across prompts
    let systems: Vec<Vec<i32>> = (0..n_system)
        .map(|s| (0..system_len).map(|i| (s * 100_000 + i) as i32).collect())
        .collect();

    let mut live: std::collections::VecDeque<(u64, usize)> = Default::default(); // (id, len)
    let mut peak_paged = 0usize;
    let mut peak_paged_live = 0usize;
    let mut peak_contig_exact = 0usize;
    let mut peak_contig_bucket = 0usize;

    let mut track = |kv: &PagedKv, live: &std::collections::VecDeque<(u64, usize)>| {
        let snap = kv.snapshot();
        peak_paged = peak_paged.max(snap.used_bytes);
        peak_paged_live = peak_paged_live.max(snap.used_bytes - snap.cached_bytes);
        let exact: usize = live.iter().map(|(_, len)| len * fpt * 4).sum();
        peak_contig_exact = peak_contig_exact.max(exact);
        // the legacy admission unit: worst-case bucket for prompt+decode
        let bucketed: usize = live
            .iter()
            .map(|(_, len)| {
                let b = buckets.iter().copied().find(|b| *b >= *len).unwrap_or(2048);
                b * fpt * 4
            })
            .sum();
        peak_contig_bucket = peak_contig_bucket.max(bucketed);
    };

    for id in 0..n_requests as u64 {
        let sys = &systems[rng.below(n_system)];
        let mut prompt = sys.clone();
        // unique suffix → divergence after the shared prefix
        prompt.extend((0..suffix_len).map(|_| 1_000_000 + rng.below(50_000) as i32));
        kv.admit(id, layout.clone(), "chai", true, &prompt)?;
        kv.commit_prefill(id)?;
        live.push_back((id, prompt.len()));
        track(&kv, &live);

        // decode the newest request to completion
        for _ in 0..decode {
            kv.ensure_append_slot(id)?;
            kv.append_committed(id, 2_000_000 + rng.below(50_000) as i32)?;
        }
        if let Some(back) = live.back_mut() {
            back.1 += decode;
        }
        track(&kv, &live);

        while live.len() > window {
            let (old, _) = live.pop_front().unwrap();
            kv.release(old)?;
        }
        track(&kv, &live);
    }
    while let Some((old, _)) = live.pop_front() {
        kv.release(old)?;
    }

    let stats = kv.stats.clone();
    let mut table = Table::new(
        "Peak K,V occupancy: shared-system-prompt workload",
        &["accounting", "peak KiB", "vs bucketed"],
    );
    let rows: Vec<(&str, usize)> = vec![
        ("contiguous, bucket worst-case (legacy admission)", peak_contig_bucket),
        ("contiguous, exact length", peak_contig_exact),
        ("paged incl. prefix cache", peak_paged),
        ("paged live blocks only", peak_paged_live),
    ];
    for (name, bytes) in &rows {
        table.row(vec![
            name.to_string(),
            format!("{}", bytes / 1024),
            format!("{:.2}x", *bytes as f64 / peak_contig_bucket as f64),
        ]);
    }
    table.print();
    println!(
        "\nprefix hit-rate {:.1}%  ({} hit / {} miss blocks), {} CoW copies, {} evictions",
        100.0 * stats.prefix_hit_rate(),
        stats.prefix_hit_blocks,
        stats.prefix_miss_blocks,
        stats.cow_copies,
        stats.evictions,
    );

    common::write_results(
        "paged",
        Json::obj(vec![
            ("requests", Json::Num(n_requests as f64)),
            ("system_prompts", Json::Num(n_system as f64)),
            ("system_len", Json::Num(system_len as f64)),
            ("suffix_len", Json::Num(suffix_len as f64)),
            ("decode", Json::Num(decode as f64)),
            ("window", Json::Num(window as f64)),
            ("block_size", Json::Num(block as f64)),
            ("peak_contig_bucket_bytes", Json::Num(peak_contig_bucket as f64)),
            ("peak_contig_exact_bytes", Json::Num(peak_contig_exact as f64)),
            ("peak_paged_bytes", Json::Num(peak_paged as f64)),
            ("peak_paged_live_bytes", Json::Num(peak_paged_live as f64)),
            ("prefix_hit_rate", Json::Num(stats.prefix_hit_rate())),
            ("prefix_hit_blocks", Json::Num(stats.prefix_hit_blocks as f64)),
            ("cow_copies", Json::Num(stats.cow_copies as f64)),
            ("evictions", Json::Num(stats.evictions as f64)),
        ]),
    );
    Ok(())
}
