//! Tables 1-4 — accuracy across attention variants:
//!   Table 1: OPT-like model (artifacts-opt): MHA / DejaVu-50% / CHAI-static / CHAI
//!   Table 2: LLaMA-like model: + DejaVu-10/30/50 and SpAtten
//!   Table 3: deeper LLaMA variant (skipped + documented if not built)
//!   Table 4: CHAI vs CHAI-QKV (prune V too) vs MHA
//!
//! Run:  cargo bench --bench bench_accuracy_tables [-- --max-items 16]

mod common;

use std::path::Path;

use chai::bench::Table;
use chai::engine::{Engine, Variant};
use chai::eval;
use chai::util::json::Json;

fn run_table(
    title: &str,
    dir: &Path,
    variants: &[Variant],
    max_items: Option<usize>,
    suites: &[&str],
) -> anyhow::Result<(Table, Vec<Json>)> {
    let engine = Engine::from_dir(dir)?;
    let mut header: Vec<&str> = vec!["method"];
    header.extend(suites);
    let mut table = Table::new(title, &header);
    let mut json_rows = Vec::new();
    let mut baseline: Vec<f64> = Vec::new();
    for (vi, v) in variants.iter().enumerate() {
        let mut row = vec![v.name()];
        let mut accs = Vec::new();
        for s in suites {
            let suite = eval::load_suite(dir, s)?;
            let acc = eval::accuracy(&engine, &suite, v, max_items)?;
            accs.push(acc);
        }
        if vi == 0 {
            baseline = accs.clone();
            row.extend(accs.iter().map(|a| format!("{a:.1}")));
        } else {
            // paper reports deltas vs MHA for non-baseline rows
            row.extend(
                accs.iter()
                    .zip(&baseline)
                    .map(|(a, b)| format!("{:+.1}", a - b)),
            );
        }
        json_rows.push(Json::obj(vec![
            ("method", Json::Str(v.name())),
            ("acc", Json::from_f64s(&accs)),
        ]));
        table.row(row);
    }
    Ok((table, json_rows))
}

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(dir) = common::require_artifacts(&args) else { return Ok(()) };
    let max_items = match args.usize("max-items", 12)? {
        0 => None,
        n => Some(n),
    };
    let mut out = Vec::new();

    // ---- Table 1: OPT-like ----------------------------------------------
    if let Some(opt_dir) = common::opt_artifacts_dir(&args) {
        let (t1, j1) = run_table(
            "Table 1: accuracy on tiny-opt-chai (OPT-66B stand-in; deltas vs MHA)",
            &opt_dir,
            &[
                Variant::Mha,
                Variant::Dejavu(50),
                Variant::ChaiStatic,
                Variant::Chai,
            ],
            max_items,
            &eval::SUITES,
        )?;
        t1.print();
        println!("paper shape: on OPT both DejaVu-50% and CHAI stay near MHA\n");
        out.push(("table1", j1));
    } else {
        println!("[skip] artifacts-opt missing: run `python -m compile.aot --model opt --out artifacts-opt --logprob-only`");
    }

    // ---- Table 2: LLaMA-like --------------------------------------------
    let (t2, j2) = run_table(
        "Table 2: accuracy on tiny-llama-chai (LLaMA-7B stand-in; deltas vs MHA)",
        &dir,
        &[
            Variant::Mha,
            Variant::Dejavu(10),
            Variant::Dejavu(30),
            Variant::Dejavu(50),
            Variant::Spatten,
            Variant::ChaiStatic,
            Variant::Chai,
        ],
        max_items,
        &eval::SUITES,
    )?;
    t2.print();
    println!("paper shape: DejaVu degrades hard beyond 10% on LLaMA-likes;");
    println!("SpAtten degrades hard; CHAI stays within a few points of MHA\n");
    out.push(("table2", j2));

    // ---- Table 3: deeper variant ----------------------------------------
    let dir33 = std::path::PathBuf::from(args.str("artifacts-33b", "artifacts-33b"));
    if dir33.join("manifest.json").exists() {
        let (t3, j3) = run_table(
            "Table 3: accuracy on tiny-llama-33b-chai (LLaMA-33B stand-in; deltas vs MHA)",
            &dir33,
            &[
                Variant::Mha,
                Variant::Dejavu(10),
                Variant::Dejavu(30),
                Variant::Dejavu(50),
                Variant::Spatten,
                Variant::ChaiStatic,
                Variant::Chai,
            ],
            max_items,
            &eval::SUITES,
        )?;
        t3.print();
        out.push(("table3", j3));
    } else {
        println!("[skip] Table 3: deeper variant not built (train with `python -m compile.train --model llama33 --out artifacts-33b`) — see EXPERIMENTS.md");
    }

    // ---- Table 4: pruning Q,K,V -----------------------------------------
    let (t4, j4) = run_table(
        "Table 4: pruning Q,K only (CHAI) vs whole head (CHAI-QKV)",
        &dir,
        &[Variant::Mha, Variant::Chai, Variant::ChaiQkv],
        max_items,
        &["arc-challenge-syn", "piqa-syn"],
    )?;
    t4.print();
    println!("paper shape: reusing V too (CHAI-QKV) loses extra accuracy\n");
    out.push(("table4", j4));

    common::write_results(
        "accuracy_tables",
        Json::Obj(
            out.into_iter()
                .map(|(k, v)| (k.to_string(), Json::Arr(v)))
                .collect(),
        ),
    );
    Ok(())
}
