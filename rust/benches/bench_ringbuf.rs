//! Microbench: the net subsystem's lock-free rings vs a
//! `Mutex<VecDeque>` inbox on the same bounded producer/consumer
//! workload — the hot-path data structures behind the reactor's token
//! fan-out (SPSC per-request event rings) and the coordinator's
//! submission inbox (MPSC).
//!
//! Run:  cargo bench --bench bench_ringbuf [-- --items 200000]
//!
//! Prints a paper-style table and writes
//! `bench_results/BENCH_ringbuf.json` (throughput in ops/s per
//! structure; no absolute thresholds — shape only, single-core CI
//! runners invert fine-grained lock costs unpredictably).

mod common;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use chai::bench::Table;
use chai::net::ring::{Mpsc, Spsc};
use chai::util::json::Json;
use chai::util::now_ms;

const CAPACITY: usize = 1024;

/// One producer thread pushes `items` u64s through the structure while
/// the bench thread pops them all; returns ops/s (an op = one
/// push+pop pair completing).
fn spsc_ring(items: usize) -> f64 {
    let ring = Arc::new(Spsc::new(CAPACITY));
    let tx = ring.clone();
    let t0 = now_ms();
    let producer = std::thread::spawn(move || {
        for i in 0..items as u64 {
            let mut v = i;
            while let Err(back) = tx.push(v) {
                v = back;
                std::thread::yield_now();
            }
        }
    });
    let mut popped = 0usize;
    let mut next = 0u64;
    while popped < items {
        match ring.pop() {
            Some(v) => {
                assert_eq!(v, next, "SPSC must stay FIFO under load");
                next += 1;
                popped += 1;
            }
            None => std::thread::yield_now(),
        }
    }
    producer.join().unwrap();
    items as f64 / ((now_ms() - t0) / 1e3).max(1e-9)
}

/// Same single-producer workload through a locked deque bounded at the
/// same capacity.
fn spsc_mutex(items: usize) -> f64 {
    let q: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
    let tx = q.clone();
    let t0 = now_ms();
    let producer = std::thread::spawn(move || {
        for i in 0..items as u64 {
            loop {
                {
                    let mut g = tx.lock().unwrap();
                    if g.len() < CAPACITY {
                        g.push_back(i);
                        break;
                    }
                }
                std::thread::yield_now();
            }
        }
    });
    let mut popped = 0usize;
    while popped < items {
        let v = q.lock().unwrap().pop_front();
        match v {
            Some(_) => popped += 1,
            None => std::thread::yield_now(),
        }
    }
    producer.join().unwrap();
    items as f64 / ((now_ms() - t0) / 1e3).max(1e-9)
}

/// `producers` threads push `items / producers` each through the MPSC
/// ring (shed-on-full handled by retry, as the coordinator's submit
/// path would under sustained overload).
fn mpsc_ring(items: usize, producers: usize) -> f64 {
    let ring = Arc::new(Mpsc::new(CAPACITY));
    let per = items / producers;
    let t0 = now_ms();
    let handles: Vec<_> = (0..producers)
        .map(|p| {
            let tx = ring.clone();
            std::thread::spawn(move || {
                for i in 0..per as u64 {
                    let mut v = (p as u64) << 32 | i;
                    while let Err(back) = tx.push(v) {
                        v = back;
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();
    let total = per * producers;
    let mut popped = 0usize;
    while popped < total {
        match ring.pop() {
            Some(_) => popped += 1,
            None => std::thread::yield_now(),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    total as f64 / ((now_ms() - t0) / 1e3).max(1e-9)
}

fn mpsc_mutex(items: usize, producers: usize) -> f64 {
    let q: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
    let per = items / producers;
    let t0 = now_ms();
    let handles: Vec<_> = (0..producers)
        .map(|p| {
            let tx = q.clone();
            std::thread::spawn(move || {
                for i in 0..per as u64 {
                    let v = (p as u64) << 32 | i;
                    loop {
                        {
                            let mut g = tx.lock().unwrap();
                            if g.len() < CAPACITY {
                                g.push_back(v);
                                break;
                            }
                        }
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();
    let total = per * producers;
    let mut popped = 0usize;
    while popped < total {
        let v = q.lock().unwrap().pop_front();
        match v {
            Some(_) => popped += 1,
            None => std::thread::yield_now(),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    total as f64 / ((now_ms() - t0) / 1e3).max(1e-9)
}

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let items = args.usize("items", 200_000)?.max(CAPACITY * 4);
    let producers = args.usize("producers", 4)?.max(2);

    let mut table = Table::new(
        "Ring buffers vs Mutex<VecDeque> (bounded producer/consumer)",
        &["structure", "producers", "items", "ops/s"],
    );
    let mut rows = Vec::new();
    let cases: [(&str, usize, f64); 4] = [
        ("spsc-ring", 1, spsc_ring(items)),
        ("spsc-mutex-deque", 1, spsc_mutex(items)),
        ("mpsc-ring", producers, mpsc_ring(items, producers)),
        ("mpsc-mutex-deque", producers, mpsc_mutex(items, producers)),
    ];
    for (name, nprod, ops) in cases {
        assert!(ops > 0.0, "{name} made no progress");
        table.row(vec![
            name.to_string(),
            nprod.to_string(),
            items.to_string(),
            format!("{ops:.0}"),
        ]);
        rows.push(Json::obj(vec![
            ("structure", Json::Str(name.into())),
            ("producers", Json::Num(nprod as f64)),
            ("items", Json::Num(items as f64)),
            ("ops_per_s", Json::Num(ops)),
        ]));
    }
    table.print();
    println!("\nshape: rings avoid the lock handoff on every push/pop of the hot paths");
    common::write_results("BENCH_ringbuf", Json::obj(vec![("rows", Json::Arr(rows))]));
    Ok(())
}
