//! Figure 1 / Figure 14 — accuracy vs FLOPs / inference time for the head
//! selection strategies: random-k, activation-informed static-k, CHAI
//! (elbow k + online membership), and the MHA reference point.
//!
//! Run:  cargo bench --bench bench_tradeoff [-- --max-items 12]

mod common;

use chai::bench::Table;
use chai::engine::{Engine, Variant};
use chai::eval;
use chai::model::flops;
use chai::model::tokenizer;
use chai::util::json::Json;
use chai::util::stats::{median, time_ms};

const SUITES: [&str; 2] = ["hellaswag-syn", "arc-easy-syn"];

fn mean_accuracy(
    engine: &Engine,
    dir: &std::path::Path,
    v: &Variant,
    max_items: Option<usize>,
) -> anyhow::Result<f64> {
    let mut acc = 0.0;
    for s in SUITES {
        let suite = eval::load_suite(dir, s)?;
        acc += eval::accuracy(engine, &suite, v, max_items)?;
    }
    Ok(acc / SUITES.len() as f64)
}

fn scoring_latency_ms(engine: &Engine, v: &Variant) -> f64 {
    let tokens = tokenizer::encode("the color of tom is red .", true, false);
    median(&time_ms(1, 3, || {
        engine.logits(&tokens, v).unwrap();
    }))
}

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(dir) = common::require_artifacts(&args) else { return Ok(()) };
    let engine = Engine::from_dir(&dir)?;
    let m = engine.manifest().clone();
    let max_items = match args.usize("max-items", 8)? {
        0 => None,
        n => Some(n),
    };
    let t_ref = 2048; // paper plots FLOPs at seq len 2048

    let mut table = Table::new(
        "Figure 1/14: accuracy vs FLOPs (seq 2048) and measured scoring latency",
        &["method", "k/layer", "GFLOPs", "flops vs MHA", "latency ms", "accuracy %"],
    );
    let mut points = Vec::new();
    let mut push = |table: &mut Table,
                    points: &mut Vec<Json>,
                    name: String,
                    k_desc: String,
                    fl: f64,
                    lat: f64,
                    acc: f64| {
        table.row(vec![
            name.clone(),
            k_desc,
            format!("{:.2}", fl / 1e9),
            format!("{:.2}x", flops::ratio_vs_mha(&m, t_ref, fl)),
            format!("{lat:.1}"),
            format!("{acc:.1}"),
        ]);
        points.push(Json::obj(vec![
            ("method", Json::Str(name)),
            ("gflops", Json::Num(fl / 1e9)),
            ("latency_ms", Json::Num(lat)),
            ("accuracy", Json::Num(acc)),
        ]));
    };

    // MHA reference
    let acc = mean_accuracy(&engine, &dir, &Variant::Mha, max_items)?;
    let lat = scoring_latency_ms(&engine, &Variant::Mha);
    push(&mut table, &mut points, "mha".into(), "16".into(), flops::mha(&m, t_ref), lat, acc);

    // random-k and static-k sweeps (paper: 4/8/16/24 of 32 heads; ours is
    // the same fractions of 16)
    for &k in &m.uniform_k_sweep.clone() {
        for random in [true, false] {
            let v = Variant::UniformK { k, random };
            let acc = mean_accuracy(&engine, &dir, &v, max_items)?;
            let lat = scoring_latency_ms(&engine, &v);
            let fl = flops::chai(&m, t_ref, &vec![k; m.model.n_layers]);
            push(&mut table, &mut points, v.name(), k.to_string(), fl, lat, acc);
        }
    }

    // CHAI (elbow k_list + online membership)
    let acc = mean_accuracy(&engine, &dir, &Variant::Chai, max_items)?;
    let lat = scoring_latency_ms(&engine, &Variant::Chai);
    let fl = flops::chai(&m, t_ref, &m.k_list);
    push(
        &mut table,
        &mut points,
        "chai".into(),
        format!("{:?}", m.k_list),
        fl,
        lat,
        acc,
    );

    table.print();
    println!("\npaper shape: CHAI sits on the pareto frontier — random-k loses");
    println!("accuracy fast; static-k is between; CHAI holds accuracy at lower FLOPs");

    common::write_results("tradeoff", Json::obj(vec![("points", Json::Arr(points))]));
    Ok(())
}
