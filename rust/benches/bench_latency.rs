//! Figure 12 — end-to-end latency: (a) time-to-first-token including
//! CHAI's clustering overhead, (b) time-to-next-token, both vs sequence
//! length, MHA vs CHAI. Prints paper-style series + speedup column.
//!
//! Run:  cargo bench --bench bench_latency [-- --iters 5 --buckets 32,128,512,2048]

mod common;

use chai::bench::{fmt_ms, Table};
use chai::engine::{Engine, Variant};
use chai::model::tokenizer;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::json::Json;
use chai::util::stats::{median, time_ms};

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(dir) = common::require_artifacts(&args) else { return Ok(()) };
    let engine = Engine::from_dir(&dir)?;
    let m = engine.manifest().clone();
    let buckets = args.usize_list("buckets", &m.decode_buckets)?;
    let iters = args.usize("iters", 3)?;
    let warmup = args.usize("warmup", 1)?;

    // ---------------- Fig 12a: time to first token -----------------------
    let mut ttft = Table::new(
        "Figure 12a: time to first token (ms) vs sequence length",
        &["seq len", "MHA", "CHAI (probe+cluster+prefill)", "speedup"],
    );
    let mut ttft_json = Vec::new();
    for &t in &buckets {
        // prompt that fills most of the bucket
        let prompt_len = t.saturating_sub(2).max(8);
        let prompt = "the color of tom is red . ".repeat(1 + prompt_len / 26);
        let prompt_tokens: Vec<i32> = tokenizer::encode(&prompt, true, false)
            .into_iter()
            .take(prompt_len)
            .collect();
        let mut padded = vec![tokenizer::PAD; t];
        padded[..prompt_tokens.len()].copy_from_slice(&prompt_tokens);
        let toks = Tensor::i32(vec![t], padded);
        let ln = Tensor::scalar_i32(prompt_tokens.len() as i32);

        // MHA prefill
        let mha_name = format!("prefill_mha_t{t}");
        engine.rt.warmup(&[&mha_name])?;
        let mha_ms = median(&time_ms(warmup, iters, || {
            engine.rt.run(&mha_name, &[In::Host(&toks), In::Host(&ln)]).unwrap();
        }));

        // CHAI: probe + cluster + clustered prefill (paper's accounting)
        let chai_name = format!("prefill_chai_t{t}");
        engine.rt.warmup(&[&chai_name, "probe_mha"])?;
        let chai_ms = median(&time_ms(warmup, iters, || {
            let (ms, _, _) = engine.online_membership(&prompt_tokens).unwrap();
            let mem: Vec<Vec<usize>> = ms.iter().map(|x| x.membership.clone()).collect();
            let reps: Vec<Vec<usize>> = ms.iter().map(|x| x.reps.clone()).collect();
            let (mt, rt_) = engine.membership_tensors(&mem, &reps, m.k_max);
            engine
                .rt
                .run(&chai_name, &[In::Host(&toks), In::Host(&ln), In::Host(&mt), In::Host(&rt_)])
                .unwrap();
        }));
        ttft.row(vec![
            t.to_string(),
            fmt_ms(mha_ms),
            fmt_ms(chai_ms),
            format!("{:.2}x", mha_ms / chai_ms),
        ]);
        ttft_json.push(Json::obj(vec![
            ("seq_len", Json::Num(t as f64)),
            ("mha_ms", Json::Num(mha_ms)),
            ("chai_ms", Json::Num(chai_ms)),
        ]));
    }
    ttft.print();

    // ---------------- Fig 12b: time to next token ------------------------
    let mut ttnt = Table::new(
        "Figure 12b: time to next token (ms) vs sequence length",
        &["seq len", "MHA", "CHAI", "speedup"],
    );
    let mut ttnt_json = Vec::new();
    let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);
    for &t in &buckets {
        let pos = Tensor::scalar_i32((t - 2) as i32);
        let tok = Tensor::scalar_i32(42);

        let kc = Tensor::zeros_f32(&[l, h, t, dh]);
        let vc = Tensor::zeros_f32(&[l, h, t, dh]);
        let mha_name = format!("decode_mha_t{t}");
        engine.rt.warmup(&[&mha_name])?;
        let mha_ms = median(&time_ms(warmup, iters, || {
            engine
                .rt
                .run(&mha_name, &[In::Host(&tok), In::Host(&pos), In::Host(&kc), In::Host(&vc)])
                .unwrap();
        }));

        let kreps: Vec<Tensor> =
            m.k_list.iter().map(|&k| Tensor::zeros_f32(&[k, t, dh])).collect();
        let mem = Tensor::zeros_i32(&[l, h]);
        let reps = Tensor::zeros_i32(&[l, m.k_max]);
        let chai_name = format!("decode_chai_t{t}");
        engine.rt.warmup(&[&chai_name])?;
        let chai_ms = median(&time_ms(warmup, iters, || {
            let mut ins: Vec<In> = vec![In::Host(&tok), In::Host(&pos)];
            for kr in &kreps {
                ins.push(In::Host(kr));
            }
            ins.push(In::Host(&vc));
            ins.push(In::Host(&mem));
            ins.push(In::Host(&reps));
            engine.rt.run(&chai_name, &ins).unwrap();
        }));
        ttnt.row(vec![
            t.to_string(),
            fmt_ms(mha_ms),
            fmt_ms(chai_ms),
            format!("{:.2}x", mha_ms / chai_ms),
        ]);
        ttnt_json.push(Json::obj(vec![
            ("seq_len", Json::Num(t as f64)),
            ("mha_ms", Json::Num(mha_ms)),
            ("chai_ms", Json::Num(chai_ms)),
        ]));
    }
    ttnt.print();
    println!("\npaper shape: CHAI speedup grows with sequence length");
    println!("(paper: up to 1.73x TTFT, up to 5x TTNT at 2048 on LLaMA-7B/V100)");

    common::write_results(
        "latency",
        Json::obj(vec![
            ("ttft", Json::Arr(ttft_json)),
            ("ttnt", Json::Arr(ttnt_json)),
            ("attn_impl", Json::Str(m.attn_impl.clone())),
        ]),
    );
    Ok(())
}
