//! End-to-end serving benchmark: coordinator + continuous batcher under a
//! Poisson trace (in-process, no TCP), CHAI vs MHA at two load levels —
//! the system-level counterpart of Figure 12.
//!
//! Run:  cargo bench --bench bench_serving [-- --requests 16]
//!       cargo bench --bench bench_serving -- --backend ref   # no artifacts needed

mod common;

use chai::bench::{poisson_trace, Table};
use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::Variant;
use chai::util::json::Json;
use chai::util::now_ms;
use chai::util::stats::{mean, percentile};

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(base_cfg) = common::serving_config(&args) else { return Ok(()) };
    let n = args.usize("requests", 12)?;
    let max_new = args.usize("max-new", 8)?;

    let mut table = Table::new(
        "Serving: Poisson trace through coordinator (continuous batching)",
        &["variant", "rate/s", "ok", "p50 ttft ms", "p95 ttft", "p50 e2e ms", "tok/s"],
    );
    let mut json_rows = Vec::new();

    for variant_name in ["mha", "chai"] {
        for rate in [2.0f64, 8.0] {
            let cfg = ServingConfig { max_batch: 8, ..base_cfg.clone() };
            let handle = Coordinator::start(cfg)?;
            let coord = handle.coordinator.clone();
            let variant = Variant::parse(variant_name)?;

            // warm executables
            coord
                .submit("the color of tom is", 2, variant.clone())
                .recv()
                .unwrap();

            let trace = poisson_trace(n, rate, max_new.saturating_sub(2).max(1), max_new, 7);
            let t0 = now_ms();
            let mut pending = Vec::new();
            for req in &trace {
                let wait = req.arrival_ms - (now_ms() - t0);
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_millis(wait as u64));
                }
                pending.push(coord.submit(&req.prompt, req.max_new, variant.clone()));
            }
            let mut ttfts = Vec::new();
            let mut e2es = Vec::new();
            let mut tokens = 0usize;
            let mut ok = 0usize;
            for rx in pending {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    ok += 1;
                    ttfts.push(r.queue_ms + r.timing.ttft_ms);
                    e2es.push(r.e2e_ms);
                    tokens += r.n_generated;
                }
            }
            let span_s = (now_ms() - t0) / 1e3;
            table.row(vec![
                variant_name.to_string(),
                format!("{rate:.0}"),
                format!("{ok}/{n}"),
                format!("{:.1}", percentile(&ttfts, 50.0)),
                format!("{:.1}", percentile(&ttfts, 95.0)),
                format!("{:.1}", percentile(&e2es, 50.0)),
                format!("{:.1}", tokens as f64 / span_s),
            ]);
            json_rows.push(Json::obj(vec![
                ("variant", Json::Str(variant_name.into())),
                ("rate", Json::Num(rate)),
                ("p50_ttft_ms", Json::Num(percentile(&ttfts, 50.0))),
                ("p50_e2e_ms", Json::Num(percentile(&e2es, 50.0))),
                ("mean_e2e_ms", Json::Num(mean(&e2es))),
                ("throughput_tok_s", Json::Num(tokens as f64 / span_s)),
            ]));
            handle.shutdown();
        }
    }
    table.print();
    println!("\nshape: CHAI sustains lower e2e latency / higher tok/s at equal load");
    common::write_results("serving", Json::obj(vec![("rows", Json::Arr(json_rows))]));
    Ok(())
}
