//! End-to-end serving benchmark: coordinator + continuous batcher under a
//! Poisson trace (in-process, no TCP), CHAI vs MHA at two load levels —
//! the system-level counterpart of Figure 12.
//!
//! Run:  cargo bench --bench bench_serving [-- --requests 16]
//!       cargo bench --bench bench_serving -- --backend ref   # no artifacts needed
//!       cargo bench --bench bench_serving -- --backend ref --smoke
//!           # CI smoke: batched (block-table-native fused ticks) vs
//!           # --no-batched-decode sequential bucket path on one burst;
//!           # asserts identical token streams, zero decode-path bucket
//!           # copies, and batched tok/s strictly above sequential;
//!           # emits bench_results/BENCH_serving.json with tokens/s +
//!           # per-tick batch occupancy (no absolute-perf thresholds)
//!       cargo bench --bench bench_serving -- --backend ref --overload
//!           # CI overload smoke: an over-capacity burst (working set
//!           # far above the KV pool) with --preempt on; asserts zero
//!           # dropped/errored requests, bounded p99 queue wait, and
//!           # that both preemption flavors fired (>=1 swap-out with a
//!           # roomy spill tier, >=1 recompute with the tier disabled);
//!           # merges an "overload" section into BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --replicas
//!           # CI router smoke: 4 data-parallel replicas (shared
//!           # weights) vs 1 on a burst — aggregate tok/s strictly
//!           # higher (multi-core runners), token streams bit-identical
//!           # across replica counts AND across all routing policies,
//!           # and prefix-affinity placement beating round-robin's
//!           # prefix-cache hit rate on a shared-system-prompt
//!           # workload; merges a "router" section into
//!           # BENCH_serving.json

mod common;

use chai::bench::{poisson_trace, Table};
use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::Variant;
use chai::router::{Frontend, Router};
use chai::scheduler::SubmitOpts;
use chai::util::json::Json;
use chai::util::now_ms;
use chai::util::stats::{mean, percentile};

/// Batched vs sequential decode on one same-instant burst of requests
/// with partially shared prompts: the block-table-native fused tick
/// must produce the exact same token streams with zero bucket-shaped
/// decode copies, and report its throughput next to the sequential
/// path's. Writes `bench_results/BENCH_serving.json`.
fn smoke(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    let n = args.usize("requests", 8)?.max(4);
    let max_new = args.usize("max-new", 8)?;
    let prompts: Vec<String> = (0..n)
        .map(|i| format!("the color of tom is case {}", i % 3)) // shared prefixes
        .collect();

    let mut table = Table::new(
        "Serving smoke: batched block-native ticks vs sequential bucket decode",
        &["mode", "ok", "tok/s", "mean batch", "decode gathers", "prefill skipped"],
    );
    let mut json_rows = Vec::new();
    let mut streams: Vec<Vec<String>> = Vec::new();
    let mut tok_s_by_mode = Vec::new();

    for (mode, batched) in [("batched", true), ("sequential", false)] {
        let cfg = ServingConfig {
            max_batch: n,
            batched_decode: batched,
            ..base_cfg.clone()
        };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        // warm the executables out of the measurement
        coord.submit("warm up please", 2, Variant::Chai).recv().unwrap();

        // best-of-3 bursts: a single wall-clock sample on a shared CI
        // runner can be skewed by one scheduler preemption; the max
        // reflects what the path can actually sustain
        let mut texts = Vec::new();
        let mut ok = 0usize;
        let mut tok_s = 0.0f64;
        for rep in 0..3 {
            let t0 = now_ms();
            let rxs: Vec<_> = prompts
                .iter()
                .map(|p| coord.submit(p, max_new, Variant::Chai))
                .collect();
            let mut rep_texts = Vec::new();
            let mut tokens = 0usize;
            let mut rep_ok = 0usize;
            for rx in rxs {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    rep_ok += 1;
                    tokens += r.n_generated;
                }
                rep_texts.push(r.text);
            }
            let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
            tok_s = tok_s.max(tokens as f64 / span_s);
            if rep == 0 {
                texts = rep_texts;
                ok = rep_ok;
            } else {
                // greedy decoding is deterministic: repeats must agree
                assert_eq!(texts, rep_texts, "[{mode}] rep {rep} diverged");
            }
        }
        let occupancy = coord.metrics.mean_ms("decode_batch");
        let gathers = coord.metrics.gauge("paged_decode_gather_copies");
        let scatters = coord.metrics.gauge("paged_decode_scatter_copies");
        let skipped = coord.metrics.gauge("paged_prefill_skipped_tokens");
        handle.shutdown();

        assert_eq!(ok, n, "[{mode}] all requests must succeed");
        if batched {
            assert_eq!(
                gathers + scatters,
                0.0,
                "batched decode must perform zero bucket-shaped K,V copies"
            );
        }
        table.row(vec![
            mode.to_string(),
            format!("{ok}/{n}"),
            format!("{tok_s:.1}"),
            format!("{occupancy:.2}"),
            format!("{gathers:.0}"),
            format!("{skipped:.0}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("mean_batch_occupancy", Json::Num(occupancy)),
            ("decode_gather_copies", Json::Num(gathers)),
            ("decode_scatter_copies", Json::Num(scatters)),
            ("prefill_skipped_tokens", Json::Num(skipped)),
        ]));
        streams.push(texts);
        tok_s_by_mode.push(tok_s);
    }

    assert_eq!(
        streams[0], streams[1],
        "batched and sequential decode must produce identical token streams"
    );
    table.print();
    // no absolute-throughput thresholds, but the ordering is the PR's
    // acceptance criterion: block-native fused ticks must beat the
    // bucket gather/scatter path at batch >= 4
    assert!(
        tok_s_by_mode[0] > tok_s_by_mode[1],
        "batched {:.1} tok/s must be strictly above sequential {:.1} tok/s at batch {n}",
        tok_s_by_mode[0],
        tok_s_by_mode[1]
    );
    common::write_results(
        "BENCH_serving",
        Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("identical_streams", Json::Bool(true)),
        ]),
    );
    Ok(())
}

/// Overload smoke: an instantaneous burst whose working set is several
/// times the KV pool, served with `--preempt` on. Two modes, both
/// over capacity: a roomy spill tier (preemptions swap out) and a
/// disabled tier (preemptions recompute on resume). Asserts the
/// scheduler's overload contract — zero dropped requests, bounded p99
/// queue wait, at least one preemption of each flavor across the two
/// modes — and merges an "overload" section into
/// `bench_results/BENCH_serving.json` next to the --smoke rows.
fn overload(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --overload needs a paged-native backend (ref); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 10)?.max(6).min(99);
    let max_new = args.usize("max-new", 10)?;
    // pool: 4 MHA-sized blocks — each session's prompt alone needs the
    // pool's admission margin, so the burst's working set is several
    // times capacity and the scheduler must preempt to drain it
    let m = if base_cfg.artifacts_dir.join("manifest.json").exists() {
        chai::config::Manifest::load(&base_cfg.artifacts_dir)?
    } else {
        chai::runtime::reference::RefBackend::toy(0).manifest().clone()
    };
    let block = chai::kv::paged::KvLayout::from_manifest(&m, chai::kv::CacheKind::Mha)
        .block_bytes(16);
    let prompts: Vec<String> = (0..n)
        .map(|i| format!("overload {i}: tom tells a rather long story"))
        .collect();

    let mut table = Table::new(
        "Serving overload: preempt-and-requeue under an over-capacity burst",
        &[
            "mode",
            "ok",
            "preempt swap",
            "preempt recomp",
            "oom",
            "p50 wait ms",
            "p99 wait ms",
            "tok/s",
        ],
    );
    let mut json_rows = Vec::new();
    for (mode, swap_blocks) in [("overload-swap", 64usize), ("overload-recompute", 0)] {
        let cfg = ServingConfig {
            max_batch: 8,
            kv_block_size: 16,
            kv_capacity_bytes: 4 * block,
            preempt: true,
            starve_ticks: 1,
            swap_blocks,
            recompute_max_tokens: 0,
            ..base_cfg.clone()
        };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        let t0 = now_ms();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| coord.submit(p, max_new, Variant::Chai))
            .collect();
        let mut ok = 0usize;
        let mut tokens = 0usize;
        let mut waits = Vec::new();
        let mut e2es = Vec::new();
        for rx in rxs {
            let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
            if r.error.is_none() {
                ok += 1;
                tokens += r.n_generated;
                waits.push(r.queue_ms);
                e2es.push(r.e2e_ms);
            }
        }
        let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
        let swaps = coord.metrics.counter("sched_preempt_swap");
        let recomputes = coord.metrics.counter("sched_preempt_recompute");
        let ooms = coord.metrics.counter("sched_preempt_oom");
        handle.shutdown();

        assert_eq!(ok, n, "[{mode}] overload must drop zero requests");
        let (p50, p99) = (percentile(&waits, 50.0), percentile(&waits, 99.0));
        // gate on e2e, not first-admission wait: queue_ms is measured to
        // the FIRST admission, so it cannot see a session parked after a
        // preemption — e2e covers the whole life including every requeue
        let p99_e2e = percentile(&e2es, 99.0);
        assert!(p99 < 120_000.0, "[{mode}] p99 queue wait {p99:.0} ms is unbounded");
        assert!(
            p99_e2e < 120_000.0,
            "[{mode}] p99 e2e {p99_e2e:.0} ms — a preempted session was parked unboundedly"
        );
        match mode {
            "overload-swap" => assert!(
                swaps >= 1,
                "[{mode}] a roomy tier under overload must record a swap-out"
            ),
            _ => assert!(
                recomputes >= 1,
                "[{mode}] a disabled tier under overload must record a recompute preemption"
            ),
        }
        table.row(vec![
            mode.to_string(),
            format!("{ok}/{n}"),
            format!("{swaps}"),
            format!("{recomputes}"),
            format!("{ooms}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{:.1}", tokens as f64 / span_s),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("requests", Json::Num(n as f64)),
            ("ok", Json::Num(ok as f64)),
            ("dropped", Json::Num((n - ok) as f64)),
            ("preempt_swap", Json::Num(swaps as f64)),
            ("preempt_recompute", Json::Num(recomputes as f64)),
            ("preempt_oom", Json::Num(ooms as f64)),
            ("p50_queue_ms", Json::Num(p50)),
            ("p99_queue_ms", Json::Num(p99)),
            ("p99_e2e_ms", Json::Num(p99_e2e)),
            ("throughput_tok_s", Json::Num(tokens as f64 / span_s)),
        ]));
    }
    table.print();

    // merge next to the --smoke rows rather than clobbering them
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("overload".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

/// One synchronized burst through a router front-end: submit every
/// prompt, wait for all, return (per-request texts, aggregate tok/s).
fn router_burst(
    router: &Router,
    prompts: &[String],
    max_new: usize,
) -> anyhow::Result<(Vec<String>, f64)> {
    let t0 = now_ms();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| router.submit_opts(SubmitOpts::new(p, max_new, Variant::Chai)).1)
        .collect();
    let mut texts = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
        anyhow::ensure!(r.error.is_none(), "burst request failed: {:?}", r.error);
        tokens += r.n_generated;
        texts.push(r.text);
    }
    let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
    Ok((texts, tokens as f64 / span_s))
}

/// Router smoke (`--replicas`): the multi-replica front-end's CI gate.
///
/// 1. **Scale**: a burst served by 4 data-parallel replicas (shared
///    weights, round-robin placement) must deliver strictly more
///    aggregate tok/s than the same burst on 1 replica (best-of-3;
///    skipped on single-core runners where data parallelism cannot
///    win), with bit-identical per-request token streams.
/// 2. **Placement transparency**: rr, least-loaded and prefix-affinity
///    must produce bit-identical token streams on a shared-system-
///    prompt workload.
/// 3. **Affinity**: on that workload, prefix-affinity must beat
///    round-robin's aggregate prefix-cache hit rate — placement is
///    what turns N private block pools back into one effective cache.
///
/// Merges a "router" section into `bench_results/BENCH_serving.json`.
fn replicas(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --replicas needs the ref backend (shared toy weights); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 12)?.max(8);
    let max_new = args.usize("max-new", 16)?;
    let fleet = args.usize("replica-count", 4)?.max(2);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);

    let mut table = Table::new(
        "Router: data-parallel replicas under a burst (shared weights)",
        &["config", "ok", "tok/s", "prefix hit rate"],
    );
    let mut json_rows = Vec::new();

    // --- 1 vs N replicas on one burst workload (rr placement) ----------
    let burst: Vec<String> = (0..n)
        .map(|i| format!("burst case {} of the tom story", i % 4)) // shared prefixes
        .collect();
    let mut tok_s_by_fleet = Vec::new();
    let mut texts_by_fleet = Vec::new();
    for replicas in [1usize, fleet] {
        let cfg = ServingConfig {
            replicas,
            route: "rr".into(),
            max_batch: 8,
            ..base_cfg.clone()
        };
        let handle = Router::start(cfg)?;
        let router = handle.router.clone();
        // best-of-3: a single wall-clock sample on a shared runner can
        // be skewed by one OS scheduler hiccup
        let mut best = 0.0f64;
        let mut texts = Vec::new();
        for rep in 0..3 {
            let (t, tok_s) = router_burst(&router, &burst, max_new)?;
            best = best.max(tok_s);
            if rep == 0 {
                texts = t;
            } else {
                assert_eq!(texts, t, "greedy decoding must repeat exactly");
            }
        }
        let hit = router.prefix_hit_rate();
        table.row(vec![
            format!("{replicas} replica(s), rr"),
            format!("{n}/{n}"),
            format!("{best:.1}"),
            format!("{hit:.3}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(format!("burst-{replicas}-replicas"))),
            ("replicas", Json::Num(replicas as f64)),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(best)),
            ("prefix_hit_rate", Json::Num(hit)),
        ]));
        tok_s_by_fleet.push(best);
        texts_by_fleet.push(texts);
        handle.shutdown();
    }
    assert_eq!(
        texts_by_fleet[0], texts_by_fleet[1],
        "replica count must not change token streams"
    );
    if cores > 1 {
        assert!(
            tok_s_by_fleet[1] > tok_s_by_fleet[0],
            "{fleet}-replica aggregate {:.1} tok/s must be strictly above 1-replica {:.1} tok/s",
            tok_s_by_fleet[1],
            tok_s_by_fleet[0]
        );
    } else {
        eprintln!("[bench] single-core runner: skipping the {fleet}-vs-1 throughput gate");
    }

    // --- placement policies on a shared-system-prompt workload ---------
    // three distinct system prompts, each spanning >1 full KV block
    // (block_size 16 tokens), with a unique per-request tail
    let sys = [
        "you are a helpful assistant for tom; answer briefly",
        "you are a meticulous reviewer of tom's code today",
        "you are a storyteller recounting the tale of tom ok",
    ];
    let affinity: Vec<String> = (0..2 * n)
        .map(|i| format!("{} q{i}", sys[i % sys.len()]))
        .collect();
    let mut texts_by_policy = Vec::new();
    let mut hit_by_policy = Vec::new();
    for route in ["rr", "least-loaded", "prefix"] {
        let cfg = ServingConfig {
            replicas: fleet,
            route: route.into(),
            max_batch: 8,
            ..base_cfg.clone()
        };
        let handle = Router::start(cfg)?;
        let router = handle.router.clone();
        let (texts, tok_s) = router_burst(&router, &affinity, 8)?;
        let hit = router.prefix_hit_rate();
        table.row(vec![
            format!("{fleet} replicas, {route}"),
            format!("{}/{}", texts.len(), affinity.len()),
            format!("{tok_s:.1}"),
            format!("{hit:.3}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(format!("affinity-{route}"))),
            ("replicas", Json::Num(fleet as f64)),
            ("requests", Json::Num(affinity.len() as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("prefix_hit_rate", Json::Num(hit)),
        ]));
        texts_by_policy.push(texts);
        hit_by_policy.push(hit);
        handle.shutdown();
    }
    table.print();
    assert_eq!(
        texts_by_policy[0], texts_by_policy[1],
        "rr and least-loaded must produce identical token streams"
    );
    assert_eq!(
        texts_by_policy[0], texts_by_policy[2],
        "rr and prefix-affinity must produce identical token streams"
    );
    // the affinity gate: routing same-prefix traffic to the replica
    // that already holds those blocks must raise the aggregate hit rate
    assert!(
        hit_by_policy[2] > hit_by_policy[0],
        "prefix-affinity hit rate {:.3} must exceed round-robin {:.3} \
         on a shared-system-prompt workload",
        hit_by_policy[2],
        hit_by_policy[0]
    );

    // merge next to the --smoke/--overload rows rather than clobbering
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("router".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(base_cfg) = common::serving_config(&args) else { return Ok(()) };
    if args.bool("smoke") {
        return smoke(&args, &base_cfg);
    }
    if args.bool("overload") {
        return overload(&args, &base_cfg);
    }
    if args.bool("replicas") {
        return replicas(&args, &base_cfg);
    }
    let n = args.usize("requests", 12)?;
    let max_new = args.usize("max-new", 8)?;

    let mut table = Table::new(
        "Serving: Poisson trace through coordinator (continuous batching)",
        &["variant", "rate/s", "ok", "p50 ttft ms", "p95 ttft", "p50 e2e ms", "tok/s"],
    );
    let mut json_rows = Vec::new();

    for variant_name in ["mha", "chai"] {
        for rate in [2.0f64, 8.0] {
            let cfg = ServingConfig { max_batch: 8, ..base_cfg.clone() };
            let handle = Coordinator::start(cfg)?;
            let coord = handle.coordinator.clone();
            let variant = Variant::parse(variant_name)?;

            // warm executables
            coord
                .submit("the color of tom is", 2, variant.clone())
                .recv()
                .unwrap();

            let trace = poisson_trace(n, rate, max_new.saturating_sub(2).max(1), max_new, 7);
            let t0 = now_ms();
            let mut pending = Vec::new();
            for req in &trace {
                let wait = req.arrival_ms - (now_ms() - t0);
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_millis(wait as u64));
                }
                pending.push(coord.submit(&req.prompt, req.max_new, variant.clone()));
            }
            let mut ttfts = Vec::new();
            let mut e2es = Vec::new();
            let mut tokens = 0usize;
            let mut ok = 0usize;
            for rx in pending {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    ok += 1;
                    ttfts.push(r.queue_ms + r.timing.ttft_ms);
                    e2es.push(r.e2e_ms);
                    tokens += r.n_generated;
                }
            }
            let span_s = (now_ms() - t0) / 1e3;
            table.row(vec![
                variant_name.to_string(),
                format!("{rate:.0}"),
                format!("{ok}/{n}"),
                format!("{:.1}", percentile(&ttfts, 50.0)),
                format!("{:.1}", percentile(&ttfts, 95.0)),
                format!("{:.1}", percentile(&e2es, 50.0)),
                format!("{:.1}", tokens as f64 / span_s),
            ]);
            json_rows.push(Json::obj(vec![
                ("variant", Json::Str(variant_name.into())),
                ("rate", Json::Num(rate)),
                ("p50_ttft_ms", Json::Num(percentile(&ttfts, 50.0))),
                ("p50_e2e_ms", Json::Num(percentile(&e2es, 50.0))),
                ("mean_e2e_ms", Json::Num(mean(&e2es))),
                ("throughput_tok_s", Json::Num(tokens as f64 / span_s)),
            ]));
            handle.shutdown();
        }
    }
    table.print();
    println!("\nshape: CHAI sustains lower e2e latency / higher tok/s at equal load");
    common::write_results("serving", Json::obj(vec![("rows", Json::Arr(json_rows))]));
    Ok(())
}
