//! End-to-end serving benchmark: coordinator + continuous batcher under a
//! Poisson trace (in-process, no TCP), CHAI vs MHA at two load levels —
//! the system-level counterpart of Figure 12.
//!
//! Run:  cargo bench --bench bench_serving [-- --requests 16]
//!       cargo bench --bench bench_serving -- --backend ref   # no artifacts needed
//!       cargo bench --bench bench_serving -- --backend ref --smoke
//!           # CI smoke: batched (block-table-native fused ticks) vs
//!           # --no-batched-decode sequential bucket path on one burst;
//!           # asserts identical token streams, zero decode-path bucket
//!           # copies, and batched tok/s strictly above sequential;
//!           # emits bench_results/BENCH_serving.json with tokens/s +
//!           # per-tick batch occupancy (no absolute-perf thresholds)
//!       cargo bench --bench bench_serving -- --backend ref --overload
//!           # CI overload smoke: an over-capacity burst (working set
//!           # far above the KV pool) with --preempt on; asserts zero
//!           # dropped/errored requests, bounded p99 queue wait, and
//!           # that both preemption flavors fired (>=1 swap-out with a
//!           # roomy spill tier, >=1 recompute with the tier disabled);
//!           # merges an "overload" section into BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --replicas
//!           # CI router smoke: 4 data-parallel replicas (shared
//!           # weights) vs 1 on a burst — aggregate tok/s strictly
//!           # higher (multi-core runners), token streams bit-identical
//!           # across replica counts AND across all routing policies,
//!           # and prefix-affinity placement beating round-robin's
//!           # prefix-cache hit rate on a shared-system-prompt
//!           # workload; merges a "router" section into
//!           # BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --connections
//!           # CI front-end fan-out gate (Linux): one epoll-driven
//!           # load generator holds 1k+ concurrent token streams
//!           # against the SAME coordinator through both transports
//!           # (`--net threads` vs `--net reactor`); asserts
//!           # bit-identical per-connection streams, zero error
//!           # terminals, p99 TTFT no worse at low concurrency and
//!           # strictly better at high concurrency, and reactor
//!           # throughput within/above bounds; merges a "connections"
//!           # section into BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --relay
//!           # CI relay-decode gate: a same-instant burst of requests
//!           # that share a >= 4-block system prompt, served with relay
//!           # decode on vs --no-relay; asserts bit-identical token
//!           # streams, relay tok/s strictly above fused, and that the
//!           # relay path actually fired (relay_groups > 0,
//!           # relay_prefix_tokens_saved > 0); merges a "relay" section
//!           # into BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --parallel
//!           # CI parallel-kernel gate: a same-instant decode-heavy
//!           # burst of DISTINCT prompts served with --threads 1 (the
//!           # exact legacy serial kernels) vs the auto-sized worker
//!           # pool; asserts bit-identical token streams, that the pool
//!           # actually fired (pool_tasks > 0), and pool tok/s strictly
//!           # above serial on multi-core runners (>= 1.8x on >= 4
//!           # cores); merges a "parallel" section into
//!           # BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --obs
//!           # CI observability gate: the decode burst with the flight
//!           # recorder off (--no-obs) vs on; asserts bit-identical
//!           # token streams, obs-on tok/s >= 0.98x obs-off (the <= 2%
//!           # overhead contract), and that the Chrome trace dump
//!           # parses and attributes >= 99% of requests; writes
//!           # bench_results/obs_trace.json (archived by CI) and merges
//!           # an "obs" section into BENCH_serving.json
//!       cargo bench --bench bench_serving -- --backend ref --failover
//!           # CI failover drill (Linux): 4 `chai replica` processes
//!           # behind the router (process transport), a burst of
//!           # streaming requests, then SIGKILL the busiest replica
//!           # mid-decode; asserts every accepted request completes on
//!           # the survivors with exactly-once, oracle-identical token
//!           # streams (zero losses, zero duplicate frames), reports
//!           # time-to-full-recovery, and merges a "failover" section
//!           # into BENCH_serving.json

mod common;

use chai::bench::{poisson_trace, Table};
use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::Variant;
use chai::router::{Frontend, Router};
use chai::scheduler::SubmitOpts;
use chai::util::json::Json;
use chai::util::now_ms;
use chai::util::stats::{mean, percentile};

/// Batched vs sequential decode on one same-instant burst of requests
/// with partially shared prompts: the block-table-native fused tick
/// must produce the exact same token streams with zero bucket-shaped
/// decode copies, and report its throughput next to the sequential
/// path's. Writes `bench_results/BENCH_serving.json`.
fn smoke(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    let n = args.usize("requests", 8)?.max(4);
    let max_new = args.usize("max-new", 8)?;
    let prompts: Vec<String> = (0..n)
        .map(|i| format!("the color of tom is case {}", i % 3)) // shared prefixes
        .collect();

    let mut table = Table::new(
        "Serving smoke: batched block-native ticks vs sequential bucket decode",
        &["mode", "ok", "tok/s", "mean batch", "decode gathers", "prefill skipped"],
    );
    let mut json_rows = Vec::new();
    let mut streams: Vec<Vec<String>> = Vec::new();
    let mut tok_s_by_mode = Vec::new();

    for (mode, batched) in [("batched", true), ("sequential", false)] {
        let cfg = ServingConfig {
            max_batch: n,
            batched_decode: batched,
            ..base_cfg.clone()
        };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        // warm the executables out of the measurement
        coord.submit("warm up please", 2, Variant::Chai).recv().unwrap();

        // best-of-3 bursts: a single wall-clock sample on a shared CI
        // runner can be skewed by one scheduler preemption; the max
        // reflects what the path can actually sustain
        let mut texts = Vec::new();
        let mut ok = 0usize;
        let mut tok_s = 0.0f64;
        for rep in 0..3 {
            let t0 = now_ms();
            let rxs: Vec<_> = prompts
                .iter()
                .map(|p| coord.submit(p, max_new, Variant::Chai))
                .collect();
            let mut rep_texts = Vec::new();
            let mut tokens = 0usize;
            let mut rep_ok = 0usize;
            for rx in rxs {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    rep_ok += 1;
                    tokens += r.n_generated;
                }
                rep_texts.push(r.text);
            }
            let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
            tok_s = tok_s.max(tokens as f64 / span_s);
            if rep == 0 {
                texts = rep_texts;
                ok = rep_ok;
            } else {
                // greedy decoding is deterministic: repeats must agree
                assert_eq!(texts, rep_texts, "[{mode}] rep {rep} diverged");
            }
        }
        let occupancy = coord.metrics.mean_ms("decode_batch");
        let gathers = coord.metrics.gauge("paged_decode_gather_copies");
        let scatters = coord.metrics.gauge("paged_decode_scatter_copies");
        let skipped = coord.metrics.gauge("paged_prefill_skipped_tokens");
        handle.shutdown();

        assert_eq!(ok, n, "[{mode}] all requests must succeed");
        if batched {
            assert_eq!(
                gathers + scatters,
                0.0,
                "batched decode must perform zero bucket-shaped K,V copies"
            );
        }
        table.row(vec![
            mode.to_string(),
            format!("{ok}/{n}"),
            format!("{tok_s:.1}"),
            format!("{occupancy:.2}"),
            format!("{gathers:.0}"),
            format!("{skipped:.0}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("mean_batch_occupancy", Json::Num(occupancy)),
            ("decode_gather_copies", Json::Num(gathers)),
            ("decode_scatter_copies", Json::Num(scatters)),
            ("prefill_skipped_tokens", Json::Num(skipped)),
        ]));
        streams.push(texts);
        tok_s_by_mode.push(tok_s);
    }

    assert_eq!(
        streams[0], streams[1],
        "batched and sequential decode must produce identical token streams"
    );
    table.print();
    // no absolute-throughput thresholds, but the ordering is the PR's
    // acceptance criterion: block-native fused ticks must beat the
    // bucket gather/scatter path at batch >= 4
    assert!(
        tok_s_by_mode[0] > tok_s_by_mode[1],
        "batched {:.1} tok/s must be strictly above sequential {:.1} tok/s at batch {n}",
        tok_s_by_mode[0],
        tok_s_by_mode[1]
    );
    common::write_results(
        "BENCH_serving",
        Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("identical_streams", Json::Bool(true)),
        ]),
    );
    Ok(())
}

/// Relay gate (`--relay`): a same-instant burst whose prompts share a
/// long system prefix (>= 4 full KV blocks), decoded with relay groups
/// on vs `--no-relay`. The relay path computes the shared-prefix
/// attention once per group (once per rep panel for CHAI) and merges
/// per-row suffixes by online softmax, so it must deliver strictly more
/// tok/s than the fused per-row path on this workload — with
/// bit-identical token streams (the merge is exact softmax algebra) and
/// the relay counters proving the fast path actually served the burst.
/// Merges a "relay" section into `bench_results/BENCH_serving.json`.
fn relay(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --relay needs a paged-native backend (ref); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 8)?.max(8);
    let max_new = args.usize("max-new", 8)?;
    // block size 8 (>= probe_tokens, so CHAI prefix sharing stays
    // sound): the 42-token system prompt spans 5 full blocks — past the
    // gate's >= 4-block bar — and prompt + decode stays inside the toy
    // model's 64-position window
    let sys = "you are a helpful assistant for tom today";
    let prompts: Vec<String> = (0..n).map(|i| format!("{sys} q{i}")).collect();

    let mut table = Table::new(
        "Relay decode: shared-system-prompt burst, relay groups vs fused rows",
        &["mode", "ok", "tok/s", "relay groups", "prefix tok saved", "fallback"],
    );
    let mut json_rows = Vec::new();
    let mut streams: Vec<Vec<String>> = Vec::new();
    let mut tok_s_by_mode = Vec::new();

    for (mode, relay_on) in [("relay", true), ("no-relay", false)] {
        let cfg = ServingConfig {
            max_batch: n,
            kv_block_size: 8,
            relay: relay_on,
            ..base_cfg.clone()
        };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        coord.submit("warm up please", 2, Variant::Chai).recv().unwrap();

        // best-of-3 bursts: one wall-clock sample on a shared runner can
        // be skewed by a single scheduler preemption
        let mut texts = Vec::new();
        let mut ok = 0usize;
        let mut tok_s = 0.0f64;
        for rep in 0..3 {
            let t0 = now_ms();
            let rxs: Vec<_> = prompts
                .iter()
                .map(|p| coord.submit(p, max_new, Variant::Chai))
                .collect();
            let mut rep_texts = Vec::new();
            let mut tokens = 0usize;
            let mut rep_ok = 0usize;
            for rx in rxs {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    rep_ok += 1;
                    tokens += r.n_generated;
                }
                rep_texts.push(r.text);
            }
            let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
            tok_s = tok_s.max(tokens as f64 / span_s);
            if rep == 0 {
                texts = rep_texts;
                ok = rep_ok;
            } else {
                assert_eq!(texts, rep_texts, "[{mode}] rep {rep} diverged");
            }
        }
        let groups = coord.metrics.gauge("relay_groups");
        let saved = coord.metrics.gauge("relay_prefix_tokens_saved");
        let fallback = coord.metrics.gauge("relay_fallback");
        handle.shutdown();

        assert_eq!(ok, n, "[{mode}] all requests must succeed");
        if relay_on {
            assert!(groups >= 1.0, "[{mode}] the shared-prefix burst must form relay groups");
            assert!(
                saved >= 1.0,
                "[{mode}] relay groups must skip shared-prefix attention positions"
            );
        } else {
            assert_eq!(groups, 0.0, "[{mode}] --no-relay must never form relay groups");
        }
        table.row(vec![
            mode.to_string(),
            format!("{ok}/{n}"),
            format!("{tok_s:.1}"),
            format!("{groups:.0}"),
            format!("{saved:.0}"),
            format!("{fallback:.0}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("relay_groups", Json::Num(groups)),
            ("relay_prefix_tokens_saved", Json::Num(saved)),
            ("relay_fallback", Json::Num(fallback)),
        ]));
        streams.push(texts);
        tok_s_by_mode.push(tok_s);
    }
    table.print();

    assert_eq!(
        streams[0], streams[1],
        "relay and fused decode must produce identical token streams"
    );
    // the PR's acceptance criterion: computing the shared prefix once
    // per batch must strictly beat recomputing it per row
    assert!(
        tok_s_by_mode[0] > tok_s_by_mode[1],
        "relay {:.1} tok/s must be strictly above fused {:.1} tok/s on a shared-prefix burst",
        tok_s_by_mode[0],
        tok_s_by_mode[1]
    );
    println!(
        "\nshape: one shared-prefix attention pass serves the whole group; \
         fused rows re-read those blocks per request"
    );

    // merge next to the other sections rather than clobbering them
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("relay".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

/// Parallel-kernel gate: a same-instant decode-heavy burst of DISTINCT
/// prompts (no shared prefix, so every row decodes through the fused
/// cluster-coherent batch whose per-row attention fans across the
/// pool), served twice from the same config: `--threads 1` — the exact
/// legacy serial kernels — vs the worker pool auto-sized from the
/// allowed-cpu mask. The kernels partition only over independent
/// output slices (DESIGN.md §Parallel kernel execution), so the token
/// streams must be bit-identical at every pool size; the pool must
/// also actually fire (pool_tasks > 0) and, on multi-core runners,
/// deliver strictly more decode tok/s — >= 1.8x on >= 4 cores.
/// Merges a "parallel" section into `bench_results/BENCH_serving.json`.
fn parallel(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --parallel needs the ref backend (pool-dispatched kernels); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 24)?.max(8);
    let max_new = args.usize("max-new", 32)?;
    let cores = chai::runtime::pool::allowed_cpu_count();
    // distinct prompts — no prefix sharing, so the burst exercises the
    // fused decode path rather than relay's shared-prefix fast path
    let prompts: Vec<String> = (0..n).map(|i| format!("parallel case {i:02} go")).collect();

    let mut table = Table::new(
        "Parallel kernels: decode-heavy burst, worker pool vs --threads 1",
        &["mode", "workers", "ok", "tok/s", "pool tasks"],
    );
    let mut json_rows = Vec::new();
    let mut streams: Vec<Vec<String>> = Vec::new();
    let mut tok_s_by_mode = Vec::new();

    for (mode, threads) in [("serial", 1usize), ("pool", 0usize)] {
        let cfg = ServingConfig { max_batch: n, threads, ..base_cfg.clone() };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        coord.submit("warm up please", 2, Variant::Mha).recv().unwrap();

        // best-of-3 bursts: one wall-clock sample on a shared runner can
        // be skewed by a single scheduler preemption
        let mut texts = Vec::new();
        let mut ok = 0usize;
        let mut tok_s = 0.0f64;
        for rep in 0..3 {
            let t0 = now_ms();
            let rxs: Vec<_> =
                prompts.iter().map(|p| coord.submit(p, max_new, Variant::Mha)).collect();
            let mut rep_texts = Vec::new();
            let mut tokens = 0usize;
            let mut rep_ok = 0usize;
            for rx in rxs {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    rep_ok += 1;
                    tokens += r.n_generated;
                }
                rep_texts.push(r.text);
            }
            let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
            tok_s = tok_s.max(tokens as f64 / span_s);
            if rep == 0 {
                texts = rep_texts;
                ok = rep_ok;
            } else {
                assert_eq!(texts, rep_texts, "[{mode}] rep {rep} diverged");
            }
        }
        let workers = coord.metrics.gauge("pool_workers");
        let tasks = coord.metrics.gauge("pool_tasks");
        handle.shutdown();

        assert_eq!(ok, n, "[{mode}] all requests must succeed");
        if threads == 1 {
            assert_eq!(workers, 1.0, "[{mode}] --threads 1 must run the exact serial path");
        } else if cores > 1 {
            assert!(workers > 1.0, "[{mode}] auto sizing must start >1 thread on {cores} cores");
            assert!(tasks > 0.0, "[{mode}] the pool must actually execute kernel tasks");
        }
        table.row(vec![
            mode.to_string(),
            format!("{workers:.0}"),
            format!("{ok}/{n}"),
            format!("{tok_s:.1}"),
            format!("{tasks:.0}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("threads", Json::Num(workers)),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("pool_tasks", Json::Num(tasks)),
        ]));
        streams.push(texts);
        tok_s_by_mode.push(tok_s);
    }
    table.print();

    assert_eq!(
        streams[0], streams[1],
        "pool size must not change token streams — kernels partition only \
         over independent output slices"
    );
    if cores >= 4 {
        // the PR's acceptance criterion on a >= 4-core runner
        assert!(
            tok_s_by_mode[1] >= 1.8 * tok_s_by_mode[0],
            "pool {:.1} tok/s must be >= 1.8x serial {:.1} tok/s on {cores} cores",
            tok_s_by_mode[1],
            tok_s_by_mode[0]
        );
    } else if cores > 1 {
        assert!(
            tok_s_by_mode[1] > tok_s_by_mode[0],
            "pool {:.1} tok/s must be strictly above serial {:.1} tok/s on {cores} cores",
            tok_s_by_mode[1],
            tok_s_by_mode[0]
        );
    } else {
        eprintln!("[bench] single-core runner: skipping the pool-vs-serial throughput gate");
    }
    println!(
        "\nshape: the same tick fans per-row attention and blocked matmul \
         tiles across the pool; --threads 1 is the bit-identical baseline"
    );

    // merge next to the other sections rather than clobbering them
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("parallel".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

/// CI observability gate: the decode-heavy burst served with the
/// flight recorder off (`--no-obs`) vs on (the default). Asserts the
/// always-on contract — token streams bit-identical, obs-on tok/s >=
/// 0.98x obs-off (<= 2% overhead, best-of-3 each), the trace dump
/// reparses as valid Chrome trace JSON and attributes >= 99% of the
/// obs-on requests (distinct queue-span trace ids) — then writes the
/// dump to `bench_results/obs_trace.json` (the CI artifact) and merges
/// an "obs" section into `bench_results/BENCH_serving.json`.
fn obs_gate(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --obs needs the ref backend (artifact-free decode burst); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 24)?.max(8);
    let max_new = args.usize("max-new", 32)?;
    let prompts: Vec<String> = (0..n).map(|i| format!("obs gate case {i:02} go")).collect();

    let mut table = Table::new(
        "Observability overhead: decode burst, flight recorder off vs on",
        &["mode", "ok", "tok/s", "spans", "traced reqs"],
    );
    let mut json_rows = Vec::new();
    let mut streams: Vec<Vec<String>> = Vec::new();
    let mut tok_s_by_mode = Vec::new();
    let mut requests_on = 0usize;
    let mut dump = Json::Null;

    // off first: its runs must leave nothing in this process's rings,
    // so the dump taken after the on-mode covers exactly the on-mode
    for (mode, obs_on) in [("obs-off", false), ("obs-on", true)] {
        let cfg = ServingConfig { max_batch: n, obs: obs_on, ..base_cfg.clone() };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        coord.submit("warm up please", 2, Variant::Mha).recv().unwrap();

        let mut texts = Vec::new();
        let mut ok = 0usize;
        let mut tok_s = 0.0f64;
        for rep in 0..3 {
            let t0 = now_ms();
            let rxs: Vec<_> =
                prompts.iter().map(|p| coord.submit(p, max_new, Variant::Mha)).collect();
            let mut rep_texts = Vec::new();
            let mut tokens = 0usize;
            let mut rep_ok = 0usize;
            for rx in rxs {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    rep_ok += 1;
                    tokens += r.n_generated;
                }
                rep_texts.push(r.text);
            }
            let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
            tok_s = tok_s.max(tokens as f64 / span_s);
            if rep == 0 {
                texts = rep_texts;
                ok = rep_ok;
            } else {
                assert_eq!(texts, rep_texts, "[{mode}] rep {rep} diverged");
            }
        }
        assert_eq!(ok, n, "[{mode}] all requests must succeed");
        let (spans, traced) = if obs_on {
            requests_on = 3 * n + 1; // three reps + the warmup
            dump = Json::parse(&Frontend::trace_json(&coord).to_string())
                .expect("trace dump must reparse as valid JSON");
            let events = dump.get("traceEvents").unwrap().arr().unwrap();
            let traced: std::collections::HashSet<u64> = events
                .iter()
                .filter(|e| e.get("name").unwrap().str().unwrap() == "queue")
                .map(|e| e.get("args").unwrap().get("trace").unwrap().num().unwrap() as u64)
                .filter(|&t| t != 0)
                .collect();
            (events.len(), traced.len())
        } else {
            (0, 0)
        };
        handle.shutdown();

        table.row(vec![
            mode.to_string(),
            format!("{ok}/{n}"),
            format!("{tok_s:.1}"),
            format!("{spans}"),
            if obs_on { format!("{traced}/{requests_on}") } else { "-".into() },
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("trace_events", Json::Num(spans as f64)),
            ("traced_requests", Json::Num(traced as f64)),
        ]));
        streams.push(texts);
        tok_s_by_mode.push(tok_s);
        if obs_on {
            // the 99% coverage gate: every admitted request minted a
            // trace id and its queue span survived in the recorder
            assert!(
                traced as f64 >= 0.99 * requests_on as f64,
                "trace covers {traced}/{requests_on} requests (< 99%)"
            );
        }
    }
    table.print();

    assert_eq!(
        streams[0], streams[1],
        "recording must never touch tokens — streams obs-off vs obs-on"
    );
    let ratio = tok_s_by_mode[1] / tok_s_by_mode[0].max(1e-9);
    assert!(
        ratio >= 0.98,
        "obs-on {:.1} tok/s must be >= 0.98x obs-off {:.1} tok/s (ratio {ratio:.4})",
        tok_s_by_mode[1],
        tok_s_by_mode[0]
    );
    println!(
        "\nshape: span recording is a couple of clock reads + one ring store \
         per tick phase; obs-on/obs-off ratio {ratio:.4} (floor 0.98)"
    );

    // the CI-archived artifact: the obs-on burst's stitched trace
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let artifact = dir.join("obs_trace.json");
    std::fs::write(&artifact, dump.to_string())?;
    eprintln!("[bench] wrote {}", artifact.display());

    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert(
        "obs".to_string(),
        Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("overhead_ratio", Json::Num(ratio)),
        ]),
    );
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

/// Overload smoke: an instantaneous burst whose working set is several
/// times the KV pool, served with `--preempt` on. Two modes, both
/// over capacity: a roomy spill tier (preemptions swap out) and a
/// disabled tier (preemptions recompute on resume). Asserts the
/// scheduler's overload contract — zero dropped requests, bounded p99
/// queue wait, at least one preemption of each flavor across the two
/// modes — and merges an "overload" section into
/// `bench_results/BENCH_serving.json` next to the --smoke rows.
fn overload(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --overload needs a paged-native backend (ref); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 10)?.max(6).min(99);
    let max_new = args.usize("max-new", 10)?;
    // pool: 4 MHA-sized blocks — each session's prompt alone needs the
    // pool's admission margin, so the burst's working set is several
    // times capacity and the scheduler must preempt to drain it
    let m = if base_cfg.artifacts_dir.join("manifest.json").exists() {
        chai::config::Manifest::load(&base_cfg.artifacts_dir)?
    } else {
        chai::runtime::reference::RefBackend::toy(0).manifest().clone()
    };
    let block = chai::kv::paged::KvLayout::from_manifest(&m, chai::kv::CacheKind::Mha)
        .block_bytes(16);
    let prompts: Vec<String> = (0..n)
        .map(|i| format!("overload {i}: tom tells a rather long story"))
        .collect();

    let mut table = Table::new(
        "Serving overload: preempt-and-requeue under an over-capacity burst",
        &[
            "mode",
            "ok",
            "preempt swap",
            "preempt recomp",
            "oom",
            "p50 wait ms",
            "p99 wait ms",
            "tok/s",
        ],
    );
    let mut json_rows = Vec::new();
    for (mode, swap_blocks) in [("overload-swap", 64usize), ("overload-recompute", 0)] {
        let cfg = ServingConfig {
            max_batch: 8,
            kv_block_size: 16,
            kv_capacity_bytes: 4 * block,
            preempt: true,
            starve_ticks: 1,
            swap_blocks,
            recompute_max_tokens: 0,
            ..base_cfg.clone()
        };
        let handle = Coordinator::start(cfg)?;
        let coord = handle.coordinator.clone();
        let t0 = now_ms();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| coord.submit(p, max_new, Variant::Chai))
            .collect();
        let mut ok = 0usize;
        let mut tokens = 0usize;
        let mut waits = Vec::new();
        let mut e2es = Vec::new();
        for rx in rxs {
            let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
            if r.error.is_none() {
                ok += 1;
                tokens += r.n_generated;
                waits.push(r.queue_ms);
                e2es.push(r.e2e_ms);
            }
        }
        let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
        let swaps = coord.metrics.counter("sched_preempt_swap");
        let recomputes = coord.metrics.counter("sched_preempt_recompute");
        let ooms = coord.metrics.counter("sched_preempt_oom");
        handle.shutdown();

        assert_eq!(ok, n, "[{mode}] overload must drop zero requests");
        let (p50, p99) = (percentile(&waits, 50.0), percentile(&waits, 99.0));
        // gate on e2e, not first-admission wait: queue_ms is measured to
        // the FIRST admission, so it cannot see a session parked after a
        // preemption — e2e covers the whole life including every requeue
        let p99_e2e = percentile(&e2es, 99.0);
        assert!(p99 < 120_000.0, "[{mode}] p99 queue wait {p99:.0} ms is unbounded");
        assert!(
            p99_e2e < 120_000.0,
            "[{mode}] p99 e2e {p99_e2e:.0} ms — a preempted session was parked unboundedly"
        );
        match mode {
            "overload-swap" => assert!(
                swaps >= 1,
                "[{mode}] a roomy tier under overload must record a swap-out"
            ),
            _ => assert!(
                recomputes >= 1,
                "[{mode}] a disabled tier under overload must record a recompute preemption"
            ),
        }
        table.row(vec![
            mode.to_string(),
            format!("{ok}/{n}"),
            format!("{swaps}"),
            format!("{recomputes}"),
            format!("{ooms}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{:.1}", tokens as f64 / span_s),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("requests", Json::Num(n as f64)),
            ("ok", Json::Num(ok as f64)),
            ("dropped", Json::Num((n - ok) as f64)),
            ("preempt_swap", Json::Num(swaps as f64)),
            ("preempt_recompute", Json::Num(recomputes as f64)),
            ("preempt_oom", Json::Num(ooms as f64)),
            ("p50_queue_ms", Json::Num(p50)),
            ("p99_queue_ms", Json::Num(p99)),
            ("p99_e2e_ms", Json::Num(p99_e2e)),
            ("throughput_tok_s", Json::Num(tokens as f64 / span_s)),
        ]));
    }
    table.print();

    // merge next to the --smoke rows rather than clobbering them
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("overload".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

/// One synchronized burst through a router front-end: submit every
/// prompt, wait for all, return (per-request texts, aggregate tok/s).
fn router_burst(
    router: &Router,
    prompts: &[String],
    max_new: usize,
) -> anyhow::Result<(Vec<String>, f64)> {
    let t0 = now_ms();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| router.submit_opts(SubmitOpts::new(p, max_new, Variant::Chai)).1)
        .collect();
    let mut texts = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
        anyhow::ensure!(r.error.is_none(), "burst request failed: {:?}", r.error);
        tokens += r.n_generated;
        texts.push(r.text);
    }
    let span_s = ((now_ms() - t0) / 1e3).max(1e-9);
    Ok((texts, tokens as f64 / span_s))
}

/// Router smoke (`--replicas`): the multi-replica front-end's CI gate.
///
/// 1. **Scale**: a burst served by 4 data-parallel replicas (shared
///    weights, round-robin placement) must deliver strictly more
///    aggregate tok/s than the same burst on 1 replica (best-of-3;
///    skipped on single-core runners where data parallelism cannot
///    win), with bit-identical per-request token streams.
/// 2. **Placement transparency**: rr, least-loaded and prefix-affinity
///    must produce bit-identical token streams on a shared-system-
///    prompt workload.
/// 3. **Affinity**: on that workload, prefix-affinity must beat
///    round-robin's aggregate prefix-cache hit rate — placement is
///    what turns N private block pools back into one effective cache.
///
/// Merges a "router" section into `bench_results/BENCH_serving.json`.
fn replicas(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --replicas needs the ref backend (shared toy weights); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 12)?.max(8);
    let max_new = args.usize("max-new", 16)?;
    let fleet = args.usize("replica-count", 4)?.max(2);
    // allowed-cpu mask, not available_parallelism: cgroup/affinity-
    // restricted CI runners report the machine's cores otherwise
    let cores = chai::runtime::pool::allowed_cpu_count();

    let mut table = Table::new(
        "Router: data-parallel replicas under a burst (shared weights)",
        &["config", "ok", "tok/s", "prefix hit rate"],
    );
    let mut json_rows = Vec::new();

    // --- 1 vs N replicas on one burst workload (rr placement) ----------
    let burst: Vec<String> = (0..n)
        .map(|i| format!("burst case {} of the tom story", i % 4)) // shared prefixes
        .collect();
    let mut tok_s_by_fleet = Vec::new();
    let mut texts_by_fleet = Vec::new();
    for replicas in [1usize, fleet] {
        let cfg = ServingConfig {
            replicas,
            route: "rr".into(),
            max_batch: 8,
            ..base_cfg.clone()
        };
        let handle = Router::start(cfg)?;
        let router = handle.router.clone();
        // best-of-3: a single wall-clock sample on a shared runner can
        // be skewed by one OS scheduler hiccup
        let mut best = 0.0f64;
        let mut texts = Vec::new();
        for rep in 0..3 {
            let (t, tok_s) = router_burst(&router, &burst, max_new)?;
            best = best.max(tok_s);
            if rep == 0 {
                texts = t;
            } else {
                assert_eq!(texts, t, "greedy decoding must repeat exactly");
            }
        }
        let hit = router.prefix_hit_rate();
        table.row(vec![
            format!("{replicas} replica(s), rr"),
            format!("{n}/{n}"),
            format!("{best:.1}"),
            format!("{hit:.3}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(format!("burst-{replicas}-replicas"))),
            ("replicas", Json::Num(replicas as f64)),
            ("requests", Json::Num(n as f64)),
            ("throughput_tok_s", Json::Num(best)),
            ("prefix_hit_rate", Json::Num(hit)),
        ]));
        tok_s_by_fleet.push(best);
        texts_by_fleet.push(texts);
        handle.shutdown();
    }
    assert_eq!(
        texts_by_fleet[0], texts_by_fleet[1],
        "replica count must not change token streams"
    );
    if cores > 1 {
        assert!(
            tok_s_by_fleet[1] > tok_s_by_fleet[0],
            "{fleet}-replica aggregate {:.1} tok/s must be strictly above 1-replica {:.1} tok/s",
            tok_s_by_fleet[1],
            tok_s_by_fleet[0]
        );
    } else {
        eprintln!("[bench] single-core runner: skipping the {fleet}-vs-1 throughput gate");
    }

    // --- placement policies on a shared-system-prompt workload ---------
    // three distinct system prompts, each spanning >1 full KV block
    // (block_size 16 tokens), with a unique per-request tail
    let sys = [
        "you are a helpful assistant for tom; answer briefly",
        "you are a meticulous reviewer of tom's code today",
        "you are a storyteller recounting the tale of tom ok",
    ];
    let affinity: Vec<String> = (0..2 * n)
        .map(|i| format!("{} q{i}", sys[i % sys.len()]))
        .collect();
    let mut texts_by_policy = Vec::new();
    let mut hit_by_policy = Vec::new();
    for route in ["rr", "least-loaded", "prefix"] {
        let cfg = ServingConfig {
            replicas: fleet,
            route: route.into(),
            max_batch: 8,
            ..base_cfg.clone()
        };
        let handle = Router::start(cfg)?;
        let router = handle.router.clone();
        let (texts, tok_s) = router_burst(&router, &affinity, 8)?;
        let hit = router.prefix_hit_rate();
        table.row(vec![
            format!("{fleet} replicas, {route}"),
            format!("{}/{}", texts.len(), affinity.len()),
            format!("{tok_s:.1}"),
            format!("{hit:.3}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(format!("affinity-{route}"))),
            ("replicas", Json::Num(fleet as f64)),
            ("requests", Json::Num(affinity.len() as f64)),
            ("throughput_tok_s", Json::Num(tok_s)),
            ("prefix_hit_rate", Json::Num(hit)),
        ]));
        texts_by_policy.push(texts);
        hit_by_policy.push(hit);
        handle.shutdown();
    }
    table.print();
    assert_eq!(
        texts_by_policy[0], texts_by_policy[1],
        "rr and least-loaded must produce identical token streams"
    );
    assert_eq!(
        texts_by_policy[0], texts_by_policy[2],
        "rr and prefix-affinity must produce identical token streams"
    );
    // the affinity gate: routing same-prefix traffic to the replica
    // that already holds those blocks must raise the aggregate hit rate
    assert!(
        hit_by_policy[2] > hit_by_policy[0],
        "prefix-affinity hit rate {:.3} must exceed round-robin {:.3} \
         on a shared-system-prompt workload",
        hit_by_policy[2],
        hit_by_policy[0]
    );

    // merge next to the --smoke/--overload rows rather than clobbering
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("router".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

/// Epoll-driven load generator for `--connections`: the bench process
/// itself multiplexes every client socket on one epoll instance, so a
/// single thread can hold thousands of concurrent token streams
/// without perturbing the server under test with thousands of client
/// threads.
#[cfg(target_os = "linux")]
mod fanout {
    use chai::net::sys::{Epoll, EpollEvent, EPOLLIN, EPOLLRDHUP};
    use chai::util::json::Json;
    use chai::util::now_ms;
    use std::io::{ErrorKind, Read, Write};
    use std::net::TcpStream;
    use std::os::unix::io::AsRawFd;

    /// What one connection received, reduced to a transport-independent
    /// signature: per-frame `(i, tok, text)` plus the terminal summary.
    /// Request ids are excluded on purpose — arrival order (and thus id
    /// assignment) differs between runs; the token streams must not.
    pub struct ConnOutcome {
        pub sig: String,
        pub ttft_ms: f64,
        pub error: Option<String>,
    }

    pub struct LevelRun {
        pub outcomes: Vec<ConnOutcome>,
        pub span_s: f64,
        pub tokens: usize,
    }

    struct C {
        stream: TcpStream,
        buf: Vec<u8>,
        fired: f64,
        ttft: f64,
        sig: String,
        done: bool,
        error: Option<String>,
    }

    /// Connect `n` sockets, fire one streaming generation on each
    /// (prompt keyed by connection index so runs are comparable), and
    /// drain every stream to its terminal line through one epoll loop.
    pub fn drive(addr: &str, n: usize, max_new: usize, deadline_s: f64) -> anyhow::Result<LevelRun> {
        let mut conns: Vec<C> = Vec::with_capacity(n);
        for i in 0..n {
            let s = TcpStream::connect(addr)
                .map_err(|e| anyhow::anyhow!("connect {} of {n}: {e}", i + 1))?;
            s.set_nodelay(true)?;
            conns.push(C {
                stream: s,
                buf: Vec::new(),
                fired: 0.0,
                ttft: -1.0,
                sig: String::new(),
                done: false,
                error: None,
            });
        }
        let ep = Epoll::new()?;
        for (i, c) in conns.iter().enumerate() {
            c.stream.set_nonblocking(true)?;
            ep.add(c.stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, i as u64)?;
        }

        // fire phase: request lines are tiny and the sockets are fresh,
        // so writes land in the send buffer without blocking
        let t0 = now_ms();
        for (i, c) in conns.iter_mut().enumerate() {
            let line = Json::obj(vec![
                ("prompt", Json::Str(format!("the color of tom is case {}", i % 5))),
                ("max_new", Json::Num(max_new as f64)),
                ("variant", Json::Str("chai".into())),
                ("stream", Json::Bool(true)),
            ])
            .to_string()
                + "\n";
            let bytes = line.as_bytes();
            let mut off = 0usize;
            while off < bytes.len() {
                match c.stream.write(&bytes[off..]) {
                    Ok(k) => off += k,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::yield_now(),
                    Err(e) => anyhow::bail!("conn {i}: request write failed: {e}"),
                }
            }
            c.fired = now_ms();
        }

        // drain phase: level-triggered reads, newline framing, terminal
        // detection by the protocol contract (a line without "tok")
        let mut live = n;
        let mut tokens = 0usize;
        let mut last_done = t0;
        let mut events = vec![EpollEvent::zeroed(); 512];
        let mut chunk = [0u8; 16 << 10];
        while live > 0 {
            anyhow::ensure!(
                (now_ms() - t0) / 1e3 < deadline_s,
                "fan-out deadline: {live}/{n} connections still streaming after {deadline_s}s"
            );
            let k = ep.wait(&mut events, 250)?;
            for ev in &events[..k] {
                let idx = ev.token() as usize;
                let c = &mut conns[idx];
                if c.done {
                    continue;
                }
                // read to WouldBlock first; an EOF only counts as an
                // error after any already-buffered lines (possibly the
                // terminal) have been parsed below
                let mut eof: Option<String> = None;
                loop {
                    match c.stream.read(&mut chunk) {
                        Ok(0) => {
                            eof = Some("closed before terminal line".into());
                            break;
                        }
                        Ok(got) => c.buf.extend_from_slice(&chunk[..got]),
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) => {
                            eof = Some(format!("read failed: {e}"));
                            break;
                        }
                    }
                }
                while !c.done {
                    let Some(pos) = c.buf.iter().position(|&b| b == b'\n') else { break };
                    let line = String::from_utf8_lossy(&c.buf[..pos]).into_owned();
                    c.buf.drain(..=pos);
                    if c.ttft < 0.0 {
                        c.ttft = now_ms() - c.fired;
                    }
                    let j = Json::parse(&line)?;
                    if j.opt("tok").is_some() {
                        tokens += 1;
                        c.sig.push_str(&format!(
                            "f {} {} {};",
                            j.get("i")?.usize()?,
                            j.get("tok")?.int()?,
                            j.get("text")?.str()?
                        ));
                    } else {
                        if let Some(e) = j.opt("error") {
                            c.error = Some(e.str().unwrap_or("?").to_string());
                        } else if j.opt("cancelled").is_some() {
                            c.error = Some("cancelled".into());
                        } else {
                            c.sig.push_str(&format!(
                                "t {} {};",
                                j.get("text")?.str()?,
                                j.get("n_generated")?.usize()?
                            ));
                        }
                        c.done = true;
                        live -= 1;
                        last_done = now_ms();
                    }
                }
                if let Some(msg) = eof {
                    if !c.done {
                        c.done = true;
                        c.error = Some(msg);
                        live -= 1;
                    }
                }
            }
        }
        Ok(LevelRun {
            span_s: ((last_done - t0) / 1e3).max(1e-9),
            tokens,
            outcomes: conns
                .into_iter()
                .map(|c| ConnOutcome {
                    sig: c.sig,
                    ttft_ms: c.ttft,
                    error: c.error,
                })
                .collect(),
        })
    }
}

/// Front-end fan-out gate (`--connections`, Linux): both transports
/// serve the identical streaming workload off the SAME coordinator —
/// first ~8 connections (the latency floor must not regress), then 1k+
/// (where thread-per-connection drowns in stacks and poll wakeups while
/// the reactor multiplexes everything on one I/O thread).
///
/// Gates: bit-identical per-connection token streams across transports
/// at both levels, zero error terminals, zero lost terminals / buffer
/// kills; at low concurrency reactor p99 TTFT within 1.5x + 25 ms and
/// tok/s >= 0.7x of threads; at high concurrency reactor p99 TTFT
/// strictly below threads and tok/s >= 0.95x (best of two attempts —
/// one wall-clock sample on a shared runner can be skewed). Merges a
/// "connections" section into `bench_results/BENCH_serving.json`.
#[cfg(target_os = "linux")]
fn connections(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    use chai::net::NetMode;
    use chai::server::Server;

    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --connections needs the ref backend (toy weights); skipping");
        return Ok(());
    }
    // each connection costs two fds (client + server end) in this one
    // process; raise RLIMIT_NOFILE and clamp the fleet to what we got
    let want = args.usize("conns", 1000)?.max(64);
    let soft = chai::net::sys::raise_nofile_limit((2 * want + 512) as u64);
    let high_n = want.min(((soft.saturating_sub(256)) / 2) as usize).max(64);
    if high_n < want {
        eprintln!(
            "[bench] RLIMIT_NOFILE soft cap {soft}: running {high_n} connections instead of {want}"
        );
    }

    let handle = Coordinator::start(ServingConfig { max_batch: 8, ..base_cfg.clone() })?;
    let coord = handle.coordinator.clone();
    coord.submit("warm up please", 2, Variant::Chai).recv().unwrap();

    // one measurement: fresh server on the shared coordinator, full
    // fan-out, transport-invariant health asserts
    let measure = |mode: NetMode, n: usize, max_new: usize| -> anyhow::Result<fanout::LevelRun> {
        let server = Server::start_with(coord.clone(), "127.0.0.1:0", mode)?;
        let run = fanout::drive(&server.addr.to_string(), n, max_new, 570.0)?;
        let stats = server.net_stats().to_json(0, mode.name());
        server.stop();
        let errors: Vec<String> = run
            .outcomes
            .iter()
            .enumerate()
            .filter_map(|(i, o)| o.error.as_ref().map(|e| format!("conn {i}: {e}")))
            .collect();
        anyhow::ensure!(
            errors.is_empty(),
            "[{}] {} of {n} streams ended in error terminals: {:?} ...",
            mode.name(),
            errors.len(),
            &errors[..errors.len().min(4)]
        );
        for key in ["net_lost_terminals", "net_conn_buffer_kills"] {
            anyhow::ensure!(
                stats.get(key)?.num()? == 0.0,
                "[{}] {key} must be 0 under a healthy fan-out: {stats:?}",
                mode.name()
            );
        }
        anyhow::ensure!(
            stats.get("net_accepted_total")?.usize()? >= n,
            "[{}] accepted fewer connections than driven",
            mode.name()
        );
        Ok(run)
    };

    let mut table = Table::new(
        "Front-end fan-out: thread-per-connection vs epoll reactor (one coordinator)",
        &["transport", "conns", "ok", "tokens", "p99 ttft ms", "tok/s"],
    );
    let mut json_rows = Vec::new();
    let row = |table: &mut Table,
                   json_rows: &mut Vec<Json>,
                   level: &str,
                   mode_name: &str,
                   n: usize,
                   run: &fanout::LevelRun,
                   p99: f64,
                   tok_s: f64| {
        table.row(vec![
            format!("{mode_name} ({level})"),
            n.to_string(),
            format!("{n}/{n}"),
            run.tokens.to_string(),
            format!("{p99:.1}"),
            format!("{tok_s:.1}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::Str(format!("{level}-{mode_name}"))),
            ("connections", Json::Num(n as f64)),
            ("tokens", Json::Num(run.tokens as f64)),
            ("p99_ttft_ms", Json::Num(p99)),
            ("throughput_tok_s", Json::Num(tok_s)),
        ]));
    };
    let summarize = |run: &fanout::LevelRun| -> (f64, f64) {
        let ttfts: Vec<f64> = run.outcomes.iter().map(|o| o.ttft_ms).collect();
        (percentile(&ttfts, 99.0), run.tokens as f64 / run.span_s)
    };
    let sigs = |run: &fanout::LevelRun| -> Vec<&str> {
        run.outcomes.iter().map(|o| o.sig.as_str()).collect()
    };

    // --- low concurrency: the latency floor must not regress ----------
    let low_n = 8usize;
    let low_max_new = args.usize("max-new", 8)?;
    let t_low = measure(NetMode::Threads, low_n, low_max_new)?;
    let r_low = measure(NetMode::Reactor, low_n, low_max_new)?;
    assert_eq!(
        sigs(&t_low),
        sigs(&r_low),
        "low concurrency: transports must produce bit-identical token streams"
    );
    let (t_p99, t_tok) = summarize(&t_low);
    let (r_p99, r_tok) = summarize(&r_low);
    row(&mut table, &mut json_rows, "low", "threads", low_n, &t_low, t_p99, t_tok);
    row(&mut table, &mut json_rows, "low", "reactor", low_n, &r_low, r_p99, r_tok);
    assert!(
        r_p99 <= t_p99 * 1.5 + 25.0,
        "low concurrency: reactor p99 TTFT {r_p99:.1} ms regressed past threads {t_p99:.1} ms"
    );
    assert!(
        r_tok >= 0.7 * t_tok,
        "low concurrency: reactor {r_tok:.1} tok/s fell below 0.7x threads {t_tok:.1} tok/s"
    );

    // --- high concurrency: 1k+ streams, one I/O thread ----------------
    // best of two attempts: the strict ordering gate is the acceptance
    // criterion, but one OS-scheduler hiccup shouldn't flake CI
    let high_max_new = args.usize("stream-max-new", 2)?.max(1);
    for attempt in 0..2 {
        let t_high = measure(NetMode::Threads, high_n, high_max_new)?;
        let r_high = measure(NetMode::Reactor, high_n, high_max_new)?;
        assert_eq!(
            sigs(&t_high),
            sigs(&r_high),
            "high concurrency: transports must produce bit-identical token streams"
        );
        let (tp, tt) = summarize(&t_high);
        let (rp, rt) = summarize(&r_high);
        let ordered = rp < tp && rt >= 0.95 * tt;
        if ordered || attempt == 1 {
            let lvl = format!("high{}", if attempt > 0 { "-retry" } else { "" });
            row(&mut table, &mut json_rows, &lvl, "threads", high_n, &t_high, tp, tt);
            row(&mut table, &mut json_rows, &lvl, "reactor", high_n, &r_high, rp, rt);
            assert!(
                rp < tp,
                "high concurrency ({high_n} conns): reactor p99 TTFT {rp:.1} ms must be \
                 strictly below threads {tp:.1} ms"
            );
            assert!(
                rt >= 0.95 * tt,
                "high concurrency ({high_n} conns): reactor {rt:.1} tok/s fell below \
                 0.95x threads {tt:.1} tok/s"
            );
            break;
        }
        eprintln!("[bench] high-concurrency ordering gate missed on attempt 1; retrying once");
    }
    handle.shutdown();
    table.print();
    println!(
        "\nshape: one epoll thread holds {high_n} streams that thread-per-connection \
         pays for in stacks and wakeups"
    );

    // merge next to the other sections rather than clobbering them
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert("connections".to_string(), Json::Arr(json_rows));
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

#[cfg(not(target_os = "linux"))]
fn connections(_args: &chai::util::args::Args, _base_cfg: &ServingConfig) -> anyhow::Result<()> {
    eprintln!("[bench] --connections exercises the epoll reactor (Linux-only); skipping");
    Ok(())
}

/// Failover drill (`--failover`, Linux): the replica mesh's CI gate.
///
/// 4 `chai replica` child processes behind the router (`--transport
/// process`, each a separate OS process speaking line-JSON over the
/// epoll reactor), a burst of streaming requests, then SIGKILL the
/// replica holding the most accepted requests while it is mid-decode.
/// The supervisor must declare it dead and requeue its accepted
/// requests on the survivors at their recorded stream offsets.
///
/// Gates: EVERY accepted request completes (zero losses), every client
/// stream is exactly-once (frame indexes 0..n-1, no gap or duplicate
/// across the replica generations) and bit-identical to a single-engine
/// oracle (greedy decode), exactly one death is recorded, and the mesh
/// serves new work afterwards. Reports time from the kill to the last
/// terminal. Merges a "failover" section into
/// `bench_results/BENCH_serving.json`.
#[cfg(target_os = "linux")]
fn failover(args: &chai::util::args::Args, base_cfg: &ServingConfig) -> anyhow::Result<()> {
    use chai::scheduler::{Response, StreamFrame};
    use std::sync::mpsc::Receiver;

    if chai::runtime::resolve_backend(base_cfg)? != "ref" {
        eprintln!("[bench] --failover needs the ref backend (toy weights); skipping");
        return Ok(());
    }
    let n = args.usize("requests", 12)?.max(8);
    let max_new = args.usize("max-new", 24)?.max(8);
    let fleet = args.usize("replica-count", 4)?.max(2);
    let prompts: Vec<String> =
        (0..n).map(|i| format!("failover tale of tom number {i}")).collect();

    // greedy-decode oracle: each prompt alone on a single-engine stack
    let oracle = Coordinator::start(base_cfg.clone())?;
    let mut want: Vec<String> = Vec::with_capacity(n);
    for p in &prompts {
        let r = oracle
            .coordinator
            .submit(p, max_new, Variant::Chai)
            .recv_timeout(std::time::Duration::from_secs(600))?;
        anyhow::ensure!(r.error.is_none(), "oracle request failed: {:?}", r.error);
        want.push(r.text);
    }
    oracle.shutdown();

    let cfg = ServingConfig {
        replicas: fleet,
        transport: "process".into(),
        replica_cmd: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_chai"))),
        probe_ms: 50,
        probe_suspect: 3,
        max_batch: 8,
        ..base_cfg.clone()
    };
    let handle = Router::start(cfg)?;
    let router = handle.router.clone();

    // fire the streaming burst and wait until every request is
    // demonstrably mid-decode (first frame received) — the kill must
    // land while the victim holds live generations, not a cold queue
    let streams: Vec<(Receiver<StreamFrame>, Receiver<Response>)> = prompts
        .iter()
        .map(|p| {
            let (tx, frames) = std::sync::mpsc::channel();
            let (_, resp) = router.submit_opts(SubmitOpts {
                stream: Some(tx.into()),
                ..SubmitOpts::new(p, max_new, Variant::Chai)
            });
            (frames, resp)
        })
        .collect();
    let mut firsts: Vec<StreamFrame> = Vec::with_capacity(n);
    for (i, (frames, _)) in streams.iter().enumerate() {
        let f = frames
            .recv_timeout(std::time::Duration::from_secs(120))
            .map_err(|e| anyhow::anyhow!("stream {i}: no first frame: {e}"))?;
        anyhow::ensure!(f.index == 0, "stream {i}: first frame index {}", f.index);
        firsts.push(f);
    }

    let victim = (0..router.replica_count())
        .max_by_key(|i| router.transport(*i).inflight())
        .unwrap();
    let in_flight = router.transport(victim).inflight();
    anyhow::ensure!(in_flight >= 1, "victim replica holds no accepted requests");
    let t_kill = now_ms();
    router.transport(victim).kill_hard()?;

    // every accepted request must complete, exactly-once and
    // oracle-identical, no matter which replica generation served it
    let mut recovered = 0usize;
    for (i, (frames, resp)) in streams.into_iter().enumerate() {
        let r = resp.recv_timeout(std::time::Duration::from_secs(600))?;
        anyhow::ensure!(r.error.is_none(), "request {i} lost to the kill: {:?}", r.error);
        anyhow::ensure!(!r.cancelled, "request {i}: spurious cancel");
        anyhow::ensure!(r.text == want[i], "request {i}: text diverged from the oracle");
        let mut got = vec![firsts[i].clone()];
        got.extend(frames.try_iter());
        anyhow::ensure!(
            got.len() == r.n_generated,
            "request {i}: {} frames for {} tokens",
            got.len(),
            r.n_generated
        );
        let mut cat = String::new();
        for (k, f) in got.iter().enumerate() {
            anyhow::ensure!(
                f.index == k,
                "request {i}: frame index {} at position {k} (gap or duplicate)",
                f.index
            );
            cat.push_str(&f.text);
        }
        anyhow::ensure!(cat == want[i], "request {i}: frames diverged from the oracle");
        recovered += 1;
    }
    let recovery_ms = now_ms() - t_kill;
    anyhow::ensure!(
        router.metrics.counter("router_replica_deaths") == 1,
        "exactly one death must be recorded"
    );
    let requeued = router.metrics.counter("router_requeued");
    anyhow::ensure!(requeued >= 1, "the victim's accepted requests must be requeued");
    anyhow::ensure!(
        recovery_ms < 120_000.0,
        "recovery took {recovery_ms:.0} ms — survivors must absorb the orphans promptly"
    );

    // the mesh keeps serving new work on the survivors
    let (_, rx) = router.submit_opts(SubmitOpts::new(&prompts[0], 4, Variant::Chai));
    let r = rx.recv_timeout(std::time::Duration::from_secs(600))?;
    anyhow::ensure!(r.error.is_none(), "post-crash submit failed: {:?}", r.error);
    handle.shutdown();

    let mut table = Table::new(
        "Failover: SIGKILL one of 4 replica processes mid-decode",
        &["fleet", "ok", "killed holding", "requeued", "recovery ms"],
    );
    table.row(vec![
        format!("{fleet} process replicas"),
        format!("{recovered}/{n}"),
        format!("{in_flight}"),
        format!("{requeued}"),
        format!("{recovery_ms:.0}"),
    ]);
    table.print();
    println!(
        "\nshape: a kill -9'd replica loses zero accepted requests; streams stay \
         exactly-once and bit-identical on the survivors"
    );

    // merge next to the other sections rather than clobbering them
    let path = std::path::Path::new("bench_results/BENCH_serving.json");
    let mut fields = match Json::parse_file(path) {
        Ok(Json::Obj(m)) => m,
        _ => Default::default(),
    };
    fields.insert(
        "failover".to_string(),
        Json::obj(vec![
            ("replicas", Json::Num(fleet as f64)),
            ("requests", Json::Num(n as f64)),
            ("ok", Json::Num(recovered as f64)),
            ("lost", Json::Num((n - recovered) as f64)),
            ("killed_holding", Json::Num(in_flight as f64)),
            ("requeued", Json::Num(requeued as f64)),
            ("recovery_ms", Json::Num(recovery_ms)),
        ]),
    );
    common::write_results("BENCH_serving", Json::Obj(fields));
    Ok(())
}

#[cfg(not(target_os = "linux"))]
fn failover(_args: &chai::util::args::Args, _base_cfg: &ServingConfig) -> anyhow::Result<()> {
    eprintln!("[bench] --failover exercises the process transport (Linux-only); skipping");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(base_cfg) = common::serving_config(&args) else { return Ok(()) };
    if args.bool("smoke") {
        return smoke(&args, &base_cfg);
    }
    if args.bool("relay") {
        return relay(&args, &base_cfg);
    }
    if args.bool("parallel") {
        return parallel(&args, &base_cfg);
    }
    if args.bool("obs") {
        return obs_gate(&args, &base_cfg);
    }
    if args.bool("overload") {
        return overload(&args, &base_cfg);
    }
    if args.bool("replicas") {
        return replicas(&args, &base_cfg);
    }
    if args.bool("connections") {
        return connections(&args, &base_cfg);
    }
    if args.bool("failover") {
        return failover(&args, &base_cfg);
    }
    let n = args.usize("requests", 12)?;
    let max_new = args.usize("max-new", 8)?;

    let mut table = Table::new(
        "Serving: Poisson trace through coordinator (continuous batching)",
        &["variant", "rate/s", "ok", "p50 ttft ms", "p95 ttft", "p50 e2e ms", "tok/s"],
    );
    let mut json_rows = Vec::new();

    for variant_name in ["mha", "chai"] {
        for rate in [2.0f64, 8.0] {
            let cfg = ServingConfig { max_batch: 8, ..base_cfg.clone() };
            let handle = Coordinator::start(cfg)?;
            let coord = handle.coordinator.clone();
            let variant = Variant::parse(variant_name)?;

            // warm executables
            coord
                .submit("the color of tom is", 2, variant.clone())
                .recv()
                .unwrap();

            let trace = poisson_trace(n, rate, max_new.saturating_sub(2).max(1), max_new, 7);
            let t0 = now_ms();
            let mut pending = Vec::new();
            for req in &trace {
                let wait = req.arrival_ms - (now_ms() - t0);
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_millis(wait as u64));
                }
                pending.push(coord.submit(&req.prompt, req.max_new, variant.clone()));
            }
            let mut ttfts = Vec::new();
            let mut e2es = Vec::new();
            let mut tokens = 0usize;
            let mut ok = 0usize;
            for rx in pending {
                let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
                if r.error.is_none() {
                    ok += 1;
                    ttfts.push(r.queue_ms + r.timing.ttft_ms);
                    e2es.push(r.e2e_ms);
                    tokens += r.n_generated;
                }
            }
            let span_s = (now_ms() - t0) / 1e3;
            table.row(vec![
                variant_name.to_string(),
                format!("{rate:.0}"),
                format!("{ok}/{n}"),
                format!("{:.1}", percentile(&ttfts, 50.0)),
                format!("{:.1}", percentile(&ttfts, 95.0)),
                format!("{:.1}", percentile(&e2es, 50.0)),
                format!("{:.1}", tokens as f64 / span_s),
            ]);
            json_rows.push(Json::obj(vec![
                ("variant", Json::Str(variant_name.into())),
                ("rate", Json::Num(rate)),
                ("p50_ttft_ms", Json::Num(percentile(&ttfts, 50.0))),
                ("p50_e2e_ms", Json::Num(percentile(&e2es, 50.0))),
                ("mean_e2e_ms", Json::Num(mean(&e2es))),
                ("throughput_tok_s", Json::Num(tokens as f64 / span_s)),
            ]));
            handle.shutdown();
        }
    }
    table.print();
    println!("\nshape: CHAI sustains lower e2e latency / higher tok/s at equal load");
    common::write_results("serving", Json::obj(vec![("rows", Json::Arr(json_rows))]));
    Ok(())
}
