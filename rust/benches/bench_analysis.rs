//! Analysis figures — regenerates the paper's observation plots:
//!   Fig 2a/2b: single-sample activations + pairwise correlation clusters
//!   Fig 4:     OPT-vs-LLaMA uniform-head contrast (entropy statistics)
//!   Fig 6/7:   per-layer average correlation (many samples vs one)
//!   Fig 8:     clustering-error elbow curves + chosen k per layer
//!   Fig 9:     cluster-membership stability vs #tokens used
//!   Fig 13:    cluster-size distribution (deepest layer)
//!
//! Run:  cargo bench --bench bench_analysis [-- --samples 32]

mod common;

use chai::baselines::dejavu;
use chai::bench::Table;
use chai::clustering::{correlation, elbow, membership};
use chai::engine::Engine;
use chai::model::tokenizer;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::json::Json;

/// Collect per-layer last-query attention features + full maps of the
/// first sample.
fn collect(
    engine: &Engine,
    samples: &[String],
) -> anyhow::Result<(Vec<Vec<Vec<f32>>>, Tensor, usize)> {
    let m = engine.manifest();
    let (l, h, t) = (m.model.n_layers, m.model.n_heads, m.analyze_bucket);
    let mut feats: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); h]; l];
    let mut first: Option<(Tensor, usize)> = None;
    for s in samples {
        let mut ids = tokenizer::encode(s, true, false);
        ids.truncate(t);
        let ln = ids.len();
        ids.resize(t, tokenizer::PAD);
        let outs = engine.rt.run(
            "analyze",
            &[In::Host(&Tensor::i32(vec![t], ids)), In::Host(&Tensor::scalar_i32(ln as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        {
            let v = maps.as_f32()?;
            for li in 0..l {
                for hi in 0..h {
                    let base = ((li * h + hi) * t + (ln - 1)) * t;
                    feats[li][hi].extend_from_slice(&v[base..base + ln]);
                }
            }
        }
        if first.is_none() {
            first = Some((maps, ln));
        }
    }
    let (maps, ln) = first.unwrap();
    Ok((feats, maps, ln))
}

fn main() -> anyhow::Result<()> {
    let args = common::bench_args();
    let Some(dir) = common::require_artifacts(&args) else { return Ok(()) };
    let n_samples = args.usize("samples", 24)?;
    let engine = Engine::from_dir(&dir)?;
    let m = engine.manifest().clone();
    let (l, h) = (m.model.n_layers, m.model.n_heads);

    let samples: Vec<String> = Json::parse_file(&dir.join("analysis_samples.json"))?
        .get("samples")?
        .str_vec()?
        .into_iter()
        .take(n_samples)
        .collect();
    eprintln!("[bench] analyzing {} samples...", samples.len());
    let (feats, first_maps, first_ln) = collect(&engine, &samples)?;

    // ---- Fig 6 (many samples) + Fig 7 (single sample) --------------------
    let mut fig67 = Table::new(
        "Figures 6+7: per-layer head correlation (N samples vs 1 sample)",
        &["layer", "mean corr (N)", "frac>0.95 (N)", "mean corr (1)", "elbow k", "k_list"],
    );
    let mut fig6_json = Vec::new();
    let t = m.analyze_bucket;
    for li in 0..l {
        let corr_n = correlation::correlation_matrix(&feats[li]);
        // single-sample features
        let v = first_maps.as_f32()?;
        let single: Vec<Vec<f32>> = (0..h)
            .map(|hi| {
                let base = ((li * h + hi) * t + (first_ln - 1)) * t;
                v[base..base + first_ln].to_vec()
            })
            .collect();
        let corr_1 = correlation::correlation_matrix(&single);
        let res = elbow::cluster_layer(&feats[li], 0);
        fig67.row(vec![
            li.to_string(),
            format!("{:.3}", correlation::mean_offdiag(&corr_n)),
            format!("{:.2}", correlation::frac_above(&corr_n, 0.95)),
            format!("{:.3}", correlation::mean_offdiag(&corr_1)),
            res.k.to_string(),
            m.k_list[li].to_string(),
        ]);
        fig6_json.push(Json::obj(vec![
            ("layer", Json::Num(li as f64)),
            ("mean_corr", Json::Num(correlation::mean_offdiag(&corr_n))),
            ("frac_above_95", Json::Num(correlation::frac_above(&corr_n, 0.95))),
        ]));
    }
    fig67.print();
    println!("paper shape: correlation increases toward later layers (Fig 6)\n");

    // ---- Fig 2b: cluster structure of the deepest layer, one sample ------
    let vv = first_maps.as_f32()?;
    let deep: Vec<Vec<f32>> = (0..h)
        .map(|hi| {
            let base = (((l - 1) * h + hi) * t + (first_ln - 1)) * t;
            vv[base..base + first_ln].to_vec()
        })
        .collect();
    let corr = correlation::correlation_matrix(&deep);
    let res = elbow::cluster_layer(&deep, 0);
    println!("Figure 2b analogue (layer {}, 1 sample): clusters {:?}", l - 1, res.membership);
    let mut within = Vec::new();
    let mut across = Vec::new();
    for i in 0..h {
        for j in i + 1..h {
            if res.membership[i] == res.membership[j] {
                within.push(corr[i][j] as f64);
            } else {
                across.push(corr[i][j] as f64);
            }
        }
    }
    println!(
        "  within-cluster corr mean {:.3}; across-cluster {:.3} (paper: within > 0.95)\n",
        chai::util::stats::mean(&within),
        chai::util::stats::mean(&across)
    );

    // ---- Fig 4: uniform-head contrast (LLaMA-like vs OPT-like) ----------
    let mut fig4 = Table::new(
        "Figure 4: near-uniform heads (probe entropy > 0.9) per model",
        &["model", "layer 0", "mid layer", "last layer"],
    );
    let probe_uniform = |engine: &Engine| -> anyhow::Result<Vec<f64>> {
        let toks = tokenizer::encode("the color of tom is red .", true, false);
        let mm = engine.manifest();
        let pb = mm.probe_bucket;
        let n = toks.len().min(mm.probe_tokens);
        let mut padded = vec![tokenizer::PAD; pb];
        padded[..n].copy_from_slice(&toks[..n]);
        let outs = engine.rt.run(
            "probe_mha",
            &[In::Host(&Tensor::i32(vec![pb], padded)), In::Host(&Tensor::scalar_i32(n as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        let ent = dejavu::head_entropy(&maps, n)?;
        Ok(ent
            .iter()
            .map(|layer| layer.iter().filter(|e| **e > 0.9).count() as f64 / layer.len() as f64)
            .collect())
    };
    let u = probe_uniform(&engine)?;
    fig4.row(vec![
        m.model.name.clone(),
        format!("{:.0}%", u[0] * 100.0),
        format!("{:.0}%", u[l / 2] * 100.0),
        format!("{:.0}%", u[l - 1] * 100.0),
    ]);
    if let Some(opt_dir) = common::opt_artifacts_dir(&args) {
        let opt_engine = Engine::from_dir(&opt_dir)?;
        let uo = probe_uniform(&opt_engine)?;
        let lo = opt_engine.manifest().model.n_layers;
        fig4.row(vec![
            opt_engine.manifest().model.name.clone(),
            format!("{:.0}%", uo[0] * 100.0),
            format!("{:.0}%", uo[lo / 2] * 100.0),
            format!("{:.0}%", uo[lo - 1] * 100.0),
        ]);
    }
    fig4.print();
    println!("paper shape: OPT has many uniform heads, LLaMA has none (Fig 4)\n");

    // ---- Fig 8: elbow curves --------------------------------------------
    let mut fig8 = Table::new(
        "Figure 8: clustering error (SSE) vs #clusters, per layer (chosen k marked *)",
        &["layer", "k=1", "k=2", "k=4", "k=8", "k=12", "k=16", "chosen"],
    );
    let errors = m.elbow_errors()?;
    for (li, errs) in errors.iter().enumerate() {
        let pick = m.k_list[li];
        let grab = |k: usize| {
            errs.get(k - 1)
                .map(|e| {
                    let s = format!("{e:.2}");
                    if k == pick { format!("{s}*") } else { s }
                })
                .unwrap_or_default()
        };
        fig8.row(vec![
            li.to_string(),
            grab(1),
            grab(2),
            grab(4),
            grab(8),
            grab(12),
            grab(16),
            pick.to_string(),
        ]);
    }
    fig8.print();
    println!("paper shape: error plateaus at the layer's intrinsic cluster count\n");

    // ---- Fig 9: membership stability vs tokens used ----------------------
    let mut fig9 = Table::new(
        "Figure 9: membership changes when adding the n-th token (deepest layer)",
        &["tokens n", "changes vs n-1 (mean over samples)"],
    );
    let max_tok = 12.min(m.analyze_bucket);
    let mut change_sums = vec![0.0f64; max_tok - 2];
    let n_stab = samples.len().min(8);
    for s in samples.iter().take(n_stab) {
        let mut ids = tokenizer::encode(s, true, false);
        ids.truncate(t);
        let ln = ids.len();
        ids.resize(t, tokenizer::PAD);
        let outs = engine.rt.run(
            "analyze",
            &[In::Host(&Tensor::i32(vec![t], ids)), In::Host(&Tensor::scalar_i32(ln as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        let v = maps.as_f32()?;
        // deepest layer maps as [H][T][T]
        let li = l - 1;
        let heads: Vec<Vec<Vec<f32>>> = (0..h)
            .map(|hi| {
                (0..max_tok)
                    .map(|q| {
                        let base = ((li * h + hi) * t + q) * t;
                        v[base..base + max_tok].to_vec()
                    })
                    .collect()
            })
            .collect();
        let curve = membership::stability_curve(&heads, max_tok, m.k_list[li], 0);
        for (i, c) in curve.iter().enumerate() {
            change_sums[i] += *c as f64;
        }
    }
    let mut fig9_json = Vec::new();
    for (i, s) in change_sums.iter().enumerate() {
        let n = i + 3; // curve starts at membership(3) vs membership(2)
        let mean = s / n_stab as f64;
        fig9.row(vec![n.to_string(), format!("{mean:.2}")]);
        fig9_json.push(Json::obj(vec![
            ("tokens", Json::Num(n as f64)),
            ("mean_changes", Json::Num(mean)),
        ]));
    }
    fig9.print();
    println!("paper shape: membership settles after ~5 tokens (Fig 9)\n");

    // ---- Fig 13: cluster-size distribution --------------------------------
    let mut sizes: Vec<usize> = Vec::new();
    for s in samples.iter().take(16) {
        let toks = tokenizer::encode(s, true, false);
        let (ms, _, _) = engine.online_membership(&toks)?;
        let deep = &ms[l - 1];
        let mut counts = vec![0usize; m.k_list[l - 1]];
        for &c in &deep.membership {
            counts[c] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        sizes.extend(counts);
    }
    let mut fig13 = Table::new(
        "Figure 13: cluster-size distribution, deepest layer (16 requests)",
        &["cluster rank", "mean heads"],
    );
    let kk = m.k_list[l - 1];
    let mut fig13_json = Vec::new();
    for rank in 0..kk {
        let vals: Vec<f64> = sizes.iter().skip(rank).step_by(kk).map(|x| *x as f64).collect();
        let mean = chai::util::stats::mean(&vals);
        fig13.row(vec![format!("#{}", rank + 1), format!("{mean:.1}")]);
        fig13_json.push(Json::Num(mean));
    }
    fig13.print();
    println!("paper shape: skewed — one or two large clusters hold most heads");

    common::write_results(
        "analysis",
        Json::obj(vec![
            ("fig6", Json::Arr(fig6_json)),
            ("fig9", Json::Arr(fig9_json)),
            ("fig13_mean_sizes", Json::Arr(fig13_json)),
        ]),
    );
    Ok(())
}
