//! XLA-vs-ref backend parity (the follow-up ROADMAP promised once the
//! ref backend landed): both backends load the same real `weights.cbt`,
//! so their logprobs must agree to float tolerance on every variant
//! whose selector inputs are deterministic.
//!
//! Runs only when `make artifacts` has produced `rust/artifacts/` (the
//! ref backend needs just the manifest + weights, no HLO); skips
//! silently — never `#[ignore]` — on a fresh checkout.

mod common;

use chai::config::ServingConfig;
use chai::engine::{Engine, Variant};
use chai::model::tokenizer;

const TOL: f32 = 1e-4;

fn engines() -> Option<(Engine, Engine)> {
    let dir = common::artifacts()?;
    let xla = Engine::load(ServingConfig {
        artifacts_dir: dir.clone(),
        backend: "xla".into(),
        ..Default::default()
    })
    .expect("xla engine");
    let reference = Engine::load(ServingConfig {
        artifacts_dir: dir,
        backend: "ref".into(),
        ..Default::default()
    })
    .expect("ref engine");
    Some((xla, reference))
}

/// Compare the real (unpadded) logit rows of two backends at `TOL`.
fn assert_close(
    xla: &chai::tensor::Tensor,
    reference: &chai::tensor::Tensor,
    n_rows: usize,
    what: &str,
) {
    assert_eq!(xla.shape, reference.shape, "{what}: shape");
    let v = xla.shape[1];
    let (a, b) = (xla.as_f32().unwrap(), reference.as_f32().unwrap());
    for i in 0..n_rows * v {
        assert!(
            (a[i] - b[i]).abs() <= TOL,
            "{what}: logit [{}, {}] xla {} vs ref {}",
            i / v,
            i % v,
            a[i],
            b[i]
        );
    }
}

#[test]
fn xla_and_ref_logprobs_agree_on_real_weights() {
    let Some((xla, reference)) = engines() else { return };
    let tokens = tokenizer::encode("the color of tom is red .", true, false);
    // deterministic-selector variants: identical inputs on both backends
    for v in [Variant::Mha, Variant::ChaiStatic, Variant::Spatten] {
        let a = xla.logits(&tokens, &v).unwrap();
        let b = reference.logits(&tokens, &v).unwrap();
        assert_close(&a, &b, tokens.len(), &v.name());
    }
}

#[test]
fn xla_and_ref_chai_agree_when_memberships_match() {
    // Online CHAI goes through the probe + k-means; tiny probe-map
    // differences can legitimately flip a cluster assignment, which
    // would compare two different (both valid) CHAI configurations. So
    // assert logit parity only when the memberships agree — and always
    // assert the probe artifact itself agrees within tolerance.
    let Some((xla, reference)) = engines() else { return };
    let tokens = tokenizer::encode("question : does tom eat rice ? answer :", true, false);
    let (ma, _, _) = xla.online_membership(&tokens).unwrap();
    let (mb, _, _) = reference.online_membership(&tokens).unwrap();
    let same = ma
        .iter()
        .zip(&mb)
        .all(|(x, y)| x.membership == y.membership && x.reps == y.reps);
    if same {
        let a = xla.logits(&tokens, &Variant::Chai).unwrap();
        let b = reference.logits(&tokens, &Variant::Chai).unwrap();
        assert_close(&a, &b, tokens.len(), "chai (matching online membership)");
    } else {
        eprintln!("[parity] online memberships diverged across backends; skipping CHAI compare");
    }
}
