//! Property tests for relay decode (shared-prefix attention computed
//! once per batch, merged by online softmax).
//!
//! The acceptance contract, exercised over random share topologies:
//!
//! 1. Backend level: `decode_paged` with relay descriptors produces
//!    logits within 1e-5 of the fused per-row oracle, and the greedy
//!    argmax never flips — including groups that gain a member
//!    mid-decode and rows whose private tails started as CoW forks of
//!    a groupmate's blocks.
//! 2. Engine level: a relay engine and a `--no-relay` engine produce
//!    identical token streams for random mixes of shared-prefix
//!    sessions, unrelated singletons, and sessions forked mid-decode —
//!    while the relay engine actually forms groups and skips prefix
//!    positions (`relay_prefix_tokens_saved > 0`).
//!
//! Everything runs artifact-free on the seeded toy model.

use std::path::PathBuf;

use chai::config::ServingConfig;
use chai::engine::{Engine, Session, Variant};
use chai::kv::paged::{KvLayout, PagedKv};
use chai::kv::CacheKind;
use chai::runtime::reference::RefBackend;
use chai::runtime::{Backend, PagedDecodeRow, RelayRef};
use chai::util::proptest::check;
use chai::util::rng::Rng;

fn toy_cfg(seed: u64, relay: bool) -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
        backend: "ref".into(),
        seed,
        relay,
        ..Default::default()
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Backend level: relay logits vs the fused oracle
// ---------------------------------------------------------------------------

/// Run one decode step on `store` for `seqs` (current length `lens[i]`,
/// feeding `toks[i]`), with or without relay descriptors over the
/// shared prefix `sp`. Returns per-row logits.
fn step(
    be: &RefBackend,
    store: &mut PagedKv,
    seqs: &[u64],
    toks: &[i32],
    lens: &[usize],
    sp: usize,
    relay: bool,
) -> Result<Vec<Vec<f32>>, String> {
    for &s in seqs {
        store.ensure_append_slot(s).map_err(|e| e.to_string())?;
    }
    let rows: Vec<PagedDecodeRow> = seqs
        .iter()
        .zip(toks)
        .zip(lens)
        .map(|((&seq, &token), &pos)| PagedDecodeRow {
            seq,
            token,
            pos,
            clusters: None,
            relay: relay.then_some(RelayRef { group: 0, prefix_len: sp }),
        })
        .collect();
    be.decode_paged(&rows, store)
        .into_iter()
        .map(|r| r.map_err(|e| format!("{e:#}")).and_then(|t| {
            t.as_f32().map(|v| v.to_vec()).map_err(|e| e.to_string())
        }))
        .collect()
}

#[test]
fn relay_decode_logits_match_fused_oracle_within_1e5() {
    check("relay-vs-fused-logits", 6, |rng| {
        let be = RefBackend::toy(rng.next_u64());
        let m = be.manifest().clone();
        let layout = KvLayout::from_manifest(&m, CacheKind::Mha);
        let b = 4usize;
        let pb = rng.range(1, 4); // shared full blocks
        let sp = pb * b;
        let n = rng.range(2, 5);
        let prefix: Vec<i32> = (0..sp).map(|_| rng.below(256) as i32).collect();

        // rows 0 and 1 share their ENTIRE prompt (partial tail adopted
        // too), so the first append slot is a CoW fork of a groupmate's
        // block; later rows diverge after the shared prefix
        let twin_tail: Vec<i32> = (0..rng.range(1, 3)).map(|_| rng.below(256) as i32).collect();
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|i| {
                let tail: Vec<i32> = if i < 2 {
                    twin_tail.clone()
                } else {
                    (0..rng.below(4)).map(|_| rng.below(256) as i32).collect()
                };
                prefix.iter().chain(tail.iter()).copied().collect()
            })
            .collect();

        // two stores populated identically: relay group vs fused oracle
        let mut kv_r = PagedKv::new(b, 1 << 24);
        let mut kv_f = PagedKv::new(b, 1 << 24);
        for (i, p) in prompts.iter().enumerate() {
            let seq = (i + 1) as u64;
            for kv in [&mut kv_r, &mut kv_f] {
                kv.admit(seq, layout.clone(), "mha", true, p).map_err(|e| e.to_string())?;
                let start = kv.adopted_prefix_len(seq).map_err(|e| e.to_string())?;
                be.prefill_paged(seq, start, None, kv).map_err(|e| e.to_string())?;
                kv.commit_prefill(seq).map_err(|e| e.to_string())?;
            }
        }

        let mut seqs: Vec<u64> = (1..=n as u64).collect();
        let mut toks: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();
        let mut lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let steps = rng.range(2, 5);
        for s in 0..steps {
            // the group gains a late member mid-decode: a fresh fork of
            // the shared prefix joins before the second step
            if s == 1 {
                let seq = (n + 1) as u64;
                for kv in [&mut kv_r, &mut kv_f] {
                    kv.admit(seq, layout.clone(), "mha", true, &prefix)
                        .map_err(|e| e.to_string())?;
                    let start = kv.adopted_prefix_len(seq).map_err(|e| e.to_string())?;
                    be.prefill_paged(seq, start, None, kv).map_err(|e| e.to_string())?;
                    kv.commit_prefill(seq).map_err(|e| e.to_string())?;
                }
                seqs.push(seq);
                toks.push(rng.below(256) as i32);
                lens.push(prefix.len());
            }
            let relayed = step(&be, &mut kv_r, &seqs, &toks, &lens, sp, true)?;
            let fused = step(&be, &mut kv_f, &seqs, &toks, &lens, sp, false)?;
            for (ri, (rl, fl)) in relayed.iter().zip(&fused).enumerate() {
                let worst = rl
                    .iter()
                    .zip(fl)
                    .map(|(a, c)| (a - c).abs())
                    .fold(0.0f32, f32::max);
                chai::prop_assert!(
                    worst <= 1e-5,
                    "step {s} row {ri}: relay logits drift {worst} > 1e-5"
                );
                chai::prop_assert!(
                    argmax(rl) == argmax(fl),
                    "step {s} row {ri}: greedy argmax flipped ({} vs {})",
                    argmax(rl),
                    argmax(fl)
                );
            }
            // commit the fused argmax as the next fed token, same on
            // both stores, so the streams stay lockstep-greedy
            for (ri, &seq) in seqs.iter().enumerate() {
                kv_r.append_committed(seq, toks[ri]).map_err(|e| e.to_string())?;
                kv_f.append_committed(seq, toks[ri]).map_err(|e| e.to_string())?;
                toks[ri] = argmax(&fused[ri]) as i32;
                lens[ri] += 1;
            }
        }
        // the relay path actually ran: one group per step
        let counts = be.exec_counts.borrow();
        let ran = counts.get("decode_relay_groups").copied().unwrap_or(0);
        chai::prop_assert!(
            ran == steps as u64,
            "expected {steps} relay group executions, got {ran}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Engine level: relay streams vs --no-relay streams
// ---------------------------------------------------------------------------

fn random_suffix(rng: &mut Rng, lo: usize, hi: usize) -> String {
    let n = rng.range(lo, hi);
    (0..n).map(|_| (rng.range(32, 127) as u8) as char).collect()
}

/// Tick `sessions` to completion; a fork of `fork_prompt` joins after
/// `fork_after` ticks. Returns every session's stream, fork last.
fn run_with_fork(
    engine: &Engine,
    sessions: &mut Vec<Session>,
    variant: &Variant,
    fork_prompt: &str,
    fork_after: usize,
    max_new: usize,
) -> Result<Vec<Vec<i32>>, String> {
    let mut ticks = 0usize;
    loop {
        if ticks == fork_after {
            let s = engine
                .start_session(fork_prompt, max_new, variant)
                .map_err(|e| e.to_string())?;
            sessions.push(s);
        }
        let mut refs: Vec<&mut Session> = sessions.iter_mut().filter(|s| !s.done).collect();
        if refs.is_empty() {
            break;
        }
        for o in engine.decode_tick(&mut refs) {
            o.map_err(|e| format!("decode_tick: {e:#}"))?;
        }
        ticks += 1;
    }
    Ok(sessions.iter().map(|s| s.tokens.clone()).collect())
}

#[test]
fn relay_streams_equal_fused_streams_across_topologies() {
    check("relay-vs-fused-streams", 6, |rng| {
        let seed = rng.next_u64();
        let variant = if rng.below(2) == 0 { Variant::Mha } else { Variant::Chai };
        // shared system prompt covering >= 2 full 16-token blocks, plus
        // per-session suffixes (empty = identical prompts), plus one
        // unrelated singleton that must quietly stay fused
        let shared = random_suffix(rng, 33, 42);
        let n = rng.range(2, 5);
        let prompts: Vec<String> = (0..n)
            .map(|_| format!("{shared}{}", random_suffix(rng, 0, 6)))
            .chain(std::iter::once(random_suffix(rng, 3, 12)))
            .collect();
        let max_new = rng.range(4, 9);
        let fork_after = rng.range(1, 3);

        let mut streams: Vec<Vec<Vec<i32>>> = Vec::new();
        for relay in [true, false] {
            let engine = Engine::load(toy_cfg(seed, relay)).map_err(|e| e.to_string())?;
            let mut sessions: Vec<Session> = prompts
                .iter()
                .map(|p| engine.start_session(p, max_new, &variant))
                .collect::<anyhow::Result<_>>()
                .map_err(|e| e.to_string())?;
            // the fork re-submits session 0's full prompt mid-decode: it
            // adopts the shared blocks while its groupmates' tails have
            // already CoW-diverged, and must regroup, never read stale
            let got = run_with_fork(
                &engine,
                &mut sessions,
                &variant,
                &prompts[0],
                fork_after,
                max_new,
            )?;
            let snap = engine.paged_snapshot().unwrap();
            if relay {
                chai::prop_assert!(
                    snap.stats.relay_groups > 0,
                    "relay engine must form groups for {n} shared-prefix sessions"
                );
                chai::prop_assert!(
                    snap.stats.relay_prefix_tokens_saved > 0,
                    "relay groups must skip prefix positions"
                );
            } else {
                chai::prop_assert!(
                    snap.stats.relay_groups == 0,
                    "--no-relay engine must never form relay groups"
                );
            }
            for s in sessions {
                engine.finish_session(s);
            }
            streams.push(got);
        }
        chai::prop_assert!(
            streams[0] == streams[1],
            "{} relay streams {:?} != fused streams {:?}",
            variant.name(),
            streams[0],
            streams[1]
        );
        Ok(())
    });
}

/// Deterministic spot check of the metrics surface: identical prompts
/// form one group per tick, savings scale with (members - 1) * prefix,
/// and the escape hatch (`relay: false`) restores the fused path with
/// the same stream.
#[test]
fn relay_metrics_count_groups_and_savings() {
    let prompt = "the color of tom is red and bob is blue"; // 40 tokens w/ bos: 2 full blocks
    let relay = Engine::load(toy_cfg(3, true)).unwrap();
    let mut sessions: Vec<Session> =
        (0..3).map(|_| relay.start_session(prompt, 5, &Variant::Chai).unwrap()).collect();
    loop {
        let mut refs: Vec<&mut Session> = sessions.iter_mut().filter(|s| !s.done).collect();
        if refs.is_empty() {
            break;
        }
        for o in relay.decode_tick(&mut refs) {
            o.unwrap();
        }
    }
    let snap = relay.paged_snapshot().unwrap();
    assert!(snap.stats.relay_groups > 0, "identical prompts must relay-group");
    // every tick saves (3 - 1) members x (>= 2 full blocks) positions
    assert!(
        snap.stats.relay_prefix_tokens_saved >= snap.stats.relay_groups * 2 * 32,
        "savings {} too small for {} groups",
        snap.stats.relay_prefix_tokens_saved,
        snap.stats.relay_groups
    );
    let streams: Vec<Vec<i32>> = sessions.iter().map(|s| s.tokens.clone()).collect();
    assert_eq!(streams[0], streams[1]);
    assert_eq!(streams[0], streams[2]);
    for s in sessions {
        relay.finish_session(s);
    }

    let fused = Engine::load(toy_cfg(3, false)).unwrap();
    let g = fused.generate(prompt, 5, &Variant::Chai).unwrap();
    assert_eq!(g.tokens, streams[0], "escape hatch must not change the stream");
    assert_eq!(fused.paged_snapshot().unwrap().stats.relay_groups, 0);
}
