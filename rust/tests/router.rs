//! Integration tests for the router front-end, token streaming,
//! request cancellation, and the serving protocol's error/shutdown
//! contracts. Everything runs unconditionally on the pure-Rust
//! reference backend (seeded toy model — no artifacts needed).
//!
//! The toy model's largest decode bucket is 64 positions, so every
//! prompt+max_new here stays under that.

mod common;

use std::path::PathBuf;
use std::time::{Duration, Instant};

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::Variant;
use chai::router::{Frontend, Router};
use chai::scheduler::SubmitOpts;
use chai::server::{Client, Server};
use chai::util::json::Json;

fn ref_cfg() -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("no-artifacts"),
        backend: "ref".into(),
        ..Default::default()
    }
}

/// Poll a metrics predicate: gauges land at the end of the retiring
/// tick, slightly after the response goes out.
fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !f() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(f(), "not reached within 30s: {what}");
}

// ---------------------------------------------------------------------------
// Streaming
// ---------------------------------------------------------------------------

#[test]
fn streaming_frames_then_terminal_summary_over_tcp() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    // oracle: the same request without streaming
    let want = client.generate("the color of tom is", 8, "chai").unwrap();
    assert!(want.opt("error").is_none(), "{want:?}");

    let mut frames: Vec<Json> = Vec::new();
    let done = client
        .generate_stream("the color of tom is", 8, "chai", |f| frames.push(f.clone()))
        .unwrap();
    assert!(done.opt("error").is_none(), "{done:?}");
    assert!(done.opt("cancelled").is_none(), "{done:?}");
    let n = done.get("n_generated").unwrap().usize().unwrap();
    assert_eq!(frames.len(), n, "one frame per decoded token");
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.get("i").unwrap().usize().unwrap(), i, "frames in order");
        assert_eq!(
            f.get("id").unwrap().usize().unwrap(),
            done.get("id").unwrap().usize().unwrap()
        );
    }
    let cat: String =
        frames.iter().map(|f| f.get("text").unwrap().str().unwrap()).collect();
    assert_eq!(
        cat,
        want.get("text").unwrap().str().unwrap(),
        "streamed frames must concatenate to the non-streaming text"
    );
    server.stop();
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Cancellation
// ---------------------------------------------------------------------------

/// The acceptance contract: aborting a mid-decode streaming session
/// returns pool occupancy to its pre-request baseline (no leaked
/// blocks) and the client receives a terminal cancelled frame. The
/// abort arrives from a DIFFERENT connection — request ids are global
/// across the front-end.
#[test]
fn cancel_mid_stream_restores_pool_baseline() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let coord = handle.coordinator.clone();
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    // 7 in-process hogs keep the continuous batch busy for ~59 ticks,
    // so the streaming victim (admitted alongside them) is guaranteed
    // to still be mid-decode when the cancel lands
    let hog_rxs: Vec<_> = (0..7)
        .map(|i| coord.submit(&format!("hog {i}"), 56, Variant::Chai))
        .collect();

    let mut stream_client = Client::connect(&addr).unwrap();
    let mut side_client = Client::connect(&addr).unwrap();
    stream_client
        .send(&Json::obj(vec![
            ("prompt", Json::Str("tom".into())),
            ("max_new", Json::Num(60.0)),
            ("variant", Json::Str("chai".into())),
            ("stream", Json::Bool(true)),
        ]))
        .unwrap();
    // the first frame proves the victim is admitted and decoding
    let first = stream_client.read_json().unwrap();
    assert!(first.opt("tok").is_some(), "expected a stream frame: {first:?}");
    let id = first.get("id").unwrap().usize().unwrap() as u64;

    let ack = side_client.cancel(id).unwrap();
    assert!(ack.get("ok").unwrap().boolean().unwrap());

    // the streaming connection drains whatever frames were in flight,
    // then sees the terminal cancelled line
    let terminal = loop {
        let j = stream_client.read_json().unwrap();
        if j.opt("tok").is_none() {
            break j;
        }
    };
    assert!(
        terminal.get("cancelled").unwrap().boolean().unwrap(),
        "client must receive a terminal cancelled frame: {terminal:?}"
    );
    assert!(
        terminal.get("n_generated").unwrap().usize().unwrap() < 60,
        "the abort must land mid-decode: {terminal:?}"
    );
    assert_eq!(coord.metrics.counter("sched_cancelled"), 1);

    // the batchmates (their blocks stayed pinned by their own refs)
    // complete normally
    for rx in hog_rxs {
        let r = rx.recv_timeout(Duration::from_secs(600)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.n_generated, 56);
    }

    // occupancy back to the pre-request baseline: zero live blocks or
    // tables anywhere (published prefix blocks live on as evictable
    // cache, which is not occupancy)
    wait_until("pool back to baseline", || {
        coord.metrics.gauge("sched_live") == 0.0
            && coord.metrics.gauge("kv_live_tables") == 0.0
            && coord.metrics.gauge("kv_live_blocks") == 0.0
    });
    server.stop();
    handle.shutdown();
}

/// Cancelling a session must not corrupt a batchmate sharing its
/// prefix blocks: the survivor's stream is bit-identical to an
/// uncontended run (the shared blocks stay pinned by the survivor's
/// refs when the victim's table is torn down).
#[test]
fn cancel_leaves_shared_prefix_batchmate_bit_identical() {
    let prompt = "tom keeps the hat in the box";
    // oracle: uncontended run on a fresh stack
    let oracle = Coordinator::start(ref_cfg()).unwrap();
    let want = oracle
        .coordinator
        .submit(prompt, 30, Variant::Chai)
        .recv_timeout(Duration::from_secs(600))
        .unwrap();
    assert!(want.error.is_none());
    oracle.shutdown();

    let handle = Coordinator::start(ref_cfg()).unwrap();
    let coord = handle.coordinator.clone();
    // victim shares the survivor's full prompt (adopts its blocks);
    // its stream channel doubles as the mid-decode synchronization
    let (tx, frames) = std::sync::mpsc::channel();
    let (victim_id, victim_rx) = coord.submit_opts(SubmitOpts {
        stream: Some(tx.into()),
        ..SubmitOpts::new(prompt, 30, Variant::Chai)
    });
    let survivor_rx = coord.submit(prompt, 30, Variant::Chai);
    // three observed frames == the victim is live and mid-decode
    for _ in 0..3 {
        frames.recv_timeout(Duration::from_secs(30)).expect("victim frame");
    }
    coord.cancel(victim_id);
    let v = victim_rx.recv_timeout(Duration::from_secs(600)).unwrap();
    assert!(v.cancelled, "{v:?}");
    assert!(v.n_generated >= 3 && v.n_generated < 30, "mid-decode abort: {v:?}");
    let s = survivor_rx.recv_timeout(Duration::from_secs(600)).unwrap();
    assert!(s.error.is_none(), "{:?}", s.error);
    assert_eq!(s.text, want.text, "survivor stream must be bit-identical");
    wait_until("no leaked tables", || {
        coord.metrics.gauge("kv_live_tables") == 0.0
    });
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Protocol error paths (satellite): malformed JSON, unknown cmd,
// oversized prompt — each an {"error":..} line, none kill the
// connection
// ---------------------------------------------------------------------------

#[test]
fn protocol_errors_never_kill_the_connection() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    // malformed JSON (raw bytes, not a JSON-encoded string)
    client.send_raw("{not json at all\n").unwrap();
    let r = client.read_json().unwrap();
    assert!(r.opt("error").is_some(), "malformed JSON must error: {r:?}");

    // unknown cmd
    let r = client
        .call(&Json::obj(vec![("cmd", Json::Str("selfdestruct".into()))]))
        .unwrap();
    assert!(
        r.get("error").unwrap().str().unwrap().contains("unknown cmd"),
        "{r:?}"
    );

    // a non-object line
    client.send_raw("42\n").unwrap();
    let r = client.read_json().unwrap();
    assert!(r.opt("error").is_some(), "non-object must error: {r:?}");

    // oversized prompt: rejected at the protocol layer before
    // tokenization
    let huge = "x".repeat(chai::server::MAX_PROMPT_BYTES + 1);
    let r = client.generate(&huge, 4, "chai").unwrap();
    assert!(
        r.get("error").unwrap().str().unwrap().contains("protocol limit"),
        "{r:?}"
    );

    // a streaming request with a bad variant errors as its first line
    let r = client
        .call(&Json::obj(vec![
            ("prompt", Json::Str("hello".into())),
            ("variant", Json::Str("warp-drive".into())),
            ("stream", Json::Bool(true)),
        ]))
        .unwrap();
    assert!(r.opt("error").is_some(), "{r:?}");

    // ...and the connection still works
    assert!(client.ping().unwrap());
    let ok = client.generate("the color of tom is", 4, "chai").unwrap();
    assert!(ok.opt("error").is_none(), "{ok:?}");

    server.stop();
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Shutdown contracts (satellites)
// ---------------------------------------------------------------------------

/// Coordinator shutdown answers every in-flight request with a
/// terminal `{"error": "shutting down"}` instead of dropping channels,
/// and refuses later submissions the same way.
#[test]
fn shutdown_answers_inflight_and_refuses_new_requests() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let coord = handle.coordinator.clone();
    // more long generations than the batch width so some are still
    // pending when shutdown lands
    let rxs: Vec<_> = (0..12)
        .map(|i| coord.submit(&format!("a long tale number {i}"), 40, Variant::Chai))
        .collect();
    wait_until("work in flight", || coord.metrics.gauge("sched_live") >= 1.0);
    handle.shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        // a response MUST arrive — the old bug left clients blocked on
        // a channel whose sender was parked in a dead queue
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("request {i} hung across shutdown: {e}"));
        if let Some(err) = r.error {
            assert!(err.contains("shutting down"), "request {i}: {err}");
        }
    }
    // submissions after shutdown get an immediate terminal error
    let rx = coord.submit("too late", 4, Variant::Chai);
    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(r.error.as_deref(), Some("shutting down"));
}

/// `Server::stop` must not leave connection threads parked in
/// `read_line`: idle clients are detected via the read timeout and the
/// threads exit.
#[test]
fn server_stop_releases_idle_connection_threads() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    // three clients connect and then go silent
    let idle: Vec<Client> = (0..3).map(|_| Client::connect(&addr).unwrap()).collect();
    wait_until("connections registered", || server.active_connections() == 3);
    let conns = server.conn_counter();
    let t0 = Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "stop must not hang on idle connections"
    );
    assert_eq!(
        conns.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "idle connection threads must observe stop and exit"
    );
    drop(idle);
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

#[test]
fn router_serves_streams_and_cancels_across_replicas() {
    let cfg = ServingConfig { replicas: 2, route: "rr".into(), ..ref_cfg() };
    let handle = Router::start(cfg).unwrap();
    let router = handle.router.clone();
    let server = Server::start(router.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut client = Client::connect(&addr).unwrap();

    // plain requests spread over both replicas and all succeed
    for i in 0..4 {
        let r = client
            .generate(&format!("the color of tom number {i}"), 4, "chai")
            .unwrap();
        assert!(r.opt("error").is_none(), "{r:?}");
    }
    assert_eq!(router.counter_sum("completed"), 4);
    assert!(router.metrics.counter("router_routed_replica_0") >= 1);
    assert!(router.metrics.counter("router_routed_replica_1") >= 1);

    // streaming through the router
    let mut frames = 0usize;
    let done = client
        .generate_stream("tom keeps the hat", 6, "chai", |_| frames += 1)
        .unwrap();
    assert!(done.opt("error").is_none(), "{done:?}");
    assert_eq!(frames, done.get("n_generated").unwrap().usize().unwrap());

    // cancel broadcast: the one replica holding the id aborts it. Hogs
    // on BOTH replicas keep ticks busy so the abort lands mid-decode.
    let hog_rxs: Vec<_> = (0..6)
        .map(|i| {
            router
                .submit_opts(SubmitOpts::new(&format!("hog {i}"), 56, Variant::Chai))
                .1
        })
        .collect();
    let mut stream_client = Client::connect(&addr).unwrap();
    stream_client
        .send(&Json::obj(vec![
            ("prompt", Json::Str("tom".into())),
            ("max_new", Json::Num(60.0)),
            ("stream", Json::Bool(true)),
        ]))
        .unwrap();
    let first = stream_client.read_json().unwrap();
    assert!(first.opt("tok").is_some(), "{first:?}");
    let id = first.get("id").unwrap().usize().unwrap() as u64;
    client.cancel(id).unwrap();
    let terminal = loop {
        let j = stream_client.read_json().unwrap();
        if j.opt("tok").is_none() {
            break j;
        }
    };
    assert!(terminal.get("cancelled").unwrap().boolean().unwrap(), "{terminal:?}");
    assert_eq!(router.counter_sum("sched_cancelled"), 1);
    for rx in hog_rxs {
        let r = rx.recv_timeout(Duration::from_secs(600)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }

    // rolled-up views carry the router section and fleet info
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.get("router").unwrap().get("replicas").unwrap().usize().unwrap(),
        2
    );
    assert_eq!(stats.get("replicas").unwrap().arr().unwrap().len(), 2);
    let info = client.info().unwrap();
    assert_eq!(info.get("replicas").unwrap().usize().unwrap(), 2);
    assert_eq!(info.get("backend").unwrap().str().unwrap(), "ref");
    let sched = client.sched().unwrap();
    assert!(sched.opt("sched_cancelled").is_some(), "{sched:?}");

    server.stop();
    handle.shutdown();
}

/// All three routing policies produce bit-identical token streams —
/// placement must never change what a request generates.
#[test]
fn routing_policies_are_stream_transparent() {
    let prompts: Vec<String> = (0..6)
        .map(|i| format!("the color of tom is case {}", i % 2))
        .collect();
    let mut texts_by_policy: Vec<Vec<String>> = Vec::new();
    for route in ["rr", "least-loaded", "prefix"] {
        let cfg = ServingConfig { replicas: 3, route: route.into(), ..ref_cfg() };
        let handle = Router::start(cfg).unwrap();
        let router = handle.router.clone();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| router.submit_opts(SubmitOpts::new(p, 6, Variant::Chai)).1)
            .collect();
        let texts: Vec<String> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv_timeout(Duration::from_secs(600)).unwrap();
                assert!(r.error.is_none(), "[{route}] {:?}", r.error);
                r.text
            })
            .collect();
        texts_by_policy.push(texts);
        handle.shutdown();
    }
    assert_eq!(texts_by_policy[0], texts_by_policy[1], "rr vs least-loaded");
    assert_eq!(texts_by_policy[0], texts_by_policy[2], "rr vs prefix-affinity");
}
