//! Property tests for intra-tick parallel kernel execution.
//!
//! The partitioning contract: every kernel splits work ONLY over
//! independent output slices (row tiles, head panels, paged rows) and
//! never splits a k-reduction, so outputs are **bitwise identical** to
//! the serial path at every pool size. Exercised here:
//!
//! 1. Kernel level: every parallelized `refkernels` entry point over
//!    random shapes, serial (no pool installed) vs pool sizes
//!    {1, 2, 3, 8} — outputs compared bit-for-bit.
//! 2. Backend level: `decode_paged` over a multi-row tick (the fused
//!    stacked path, attention fanned across the pool) produces logits
//!    bit-for-bit equal to one-row-at-a-time decodes, and the fused
//!    counter fires.
//! 3. Engine level: token streams are identical `--threads 1` vs
//!    `--threads {2, 3, 8}` over a topology mixing relay groups,
//!    independent fused MHA rows, and clustered (CHAI) rows.
//!
//! Everything runs artifact-free on the seeded toy model.

use std::path::PathBuf;
use std::sync::Arc;

use chai::config::ServingConfig;
use chai::engine::{Engine, Session, Variant};
use chai::kv::paged::{KvLayout, PagedKv};
use chai::kv::CacheKind;
use chai::runtime::pool::{self, Pool};
use chai::runtime::reference::RefBackend;
use chai::runtime::{Backend, PagedDecodeRow};
use chai::util::proptest::check;
use chai::util::rng::Rng;

// ---------------------------------------------------------------------------
// Kernel level: bitwise identity across pool sizes
// ---------------------------------------------------------------------------

use chai::runtime::refkernels as rk;

fn rand_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.below(2001) as f32 / 1000.0) - 1.0).collect()
}

/// Random shapes + operands for one round of every parallel kernel.
struct KernelInputs {
    t: usize,
    d: usize,
    h: usize,
    dh: usize,
    f: usize,
    a: Vec<f32>,
    b: Vec<f32>,
    xn: Vec<f32>,
    wqkv: Vec<f32>,
    wg: Vec<f32>,
    wu: Vec<f32>,
    wd: Vec<f32>,
    norm_w: Vec<f32>,
    heads: Vec<usize>,
    positions: Vec<usize>,
    qkv_flat: Vec<f32>,
    // paged attention: slab-resident K,V with k_base = 0 and
    // v_base = h * bsz * dh (one layer's worth of panels per block)
    bsz: usize,
    tq: usize,
    q_off: usize,
    len: usize,
    q_paged: Vec<f32>,
    slabs: Vec<Vec<f32>>,
    // relay scores over the leading full blocks
    n_relay: usize,
    prefix_len: usize,
    q_relay: Vec<f32>,
}

impl KernelInputs {
    fn random(rng: &mut Rng) -> KernelInputs {
        let t = rng.range(1, 9);
        let d = rng.range(4, 33);
        let h = rng.range(1, 5);
        let dh = rng.range(2, 7);
        let f = rng.range(8, 41);
        // a random non-empty head subset (the CHAI reps shape)
        let mut heads: Vec<usize> = (0..h).filter(|_| rng.below(2) == 0).collect();
        if heads.is_empty() {
            heads.push(rng.below(h));
        }
        let bsz = 4usize;
        let tq = rng.range(1, 4);
        let q_off = rng.below(2 * bsz);
        let len = q_off + tq;
        let n_blocks = len.div_ceil(bsz);
        let slab = 2 * h * bsz * dh;
        let n_relay = rng.range(2, 5);
        let pb = rng.range(1, 3);
        let prefix_len = pb * bsz;
        let relay_blocks = pb.max(n_blocks);
        KernelInputs {
            t,
            d,
            h,
            dh,
            f,
            a: rand_f32s(rng, t * d),
            b: rand_f32s(rng, d * f),
            xn: rand_f32s(rng, t * d),
            wqkv: rand_f32s(rng, d * h * dh),
            wg: rand_f32s(rng, d * f),
            wu: rand_f32s(rng, d * f),
            wd: rand_f32s(rng, f * d),
            norm_w: rand_f32s(rng, d),
            heads,
            positions: (0..t).map(|_| rng.below(64)).collect(),
            qkv_flat: rand_f32s(rng, h * t * dh),
            bsz,
            tq,
            q_off,
            len,
            q_paged: rand_f32s(rng, h * tq * dh),
            slabs: (0..relay_blocks).map(|_| rand_f32s(rng, slab)).collect(),
            n_relay,
            prefix_len,
            q_relay: rand_f32s(rng, h * n_relay * dh),
        }
    }
}

/// Run every parallelized kernel once; outputs in a fixed order.
fn run_kernels(inp: &KernelInputs) -> Vec<Vec<f32>> {
    let (t, d, h, dh, f) = (inp.t, inp.d, inp.h, inp.dh, inp.f);
    let mut outs = Vec::new();
    outs.push(rk::matmul(&inp.a, &inp.b, t, d, f));
    // ragged panel width on purpose
    let bp = rk::pack_b(&inp.b, d, f, 5);
    outs.push(rk::matmul_packed(&inp.a, &bp, t));
    outs.push(rk::rmsnorm(&inp.xn, &inp.norm_w, t, d, 1e-5));
    let mut roped = inp.qkv_flat.clone();
    rk::rope(&mut roped, &inp.positions, h, t, dh, 10000.0);
    outs.push(roped);
    outs.push(rk::project_heads(&inp.xn, &inp.wqkv, &inp.heads, t, d, h, dh));
    let wp = rk::pack_b(&inp.wqkv, d, h * dh, dh);
    let mut projected = vec![1.0f32; inp.heads.len() * t * dh];
    rk::project_heads_packed_into(&inp.xn, &wp, &inp.heads, t, d, h, dh, &mut projected);
    outs.push(projected);
    outs.push(rk::swiglu(&inp.xn, &inp.wg, &inp.wu, &inp.wd, t, d, f));
    let (pg, pu, pd) = (
        rk::pack_b(&inp.wg, d, f, rk::PANEL),
        rk::pack_b(&inp.wu, d, f, rk::PANEL),
        rk::pack_b(&inp.wd, f, d, rk::PANEL),
    );
    let mut gate = vec![1.0f32; t * f];
    let mut up = vec![1.0f32; t * f];
    let mut mlp = vec![1.0f32; t * d];
    rk::swiglu_packed_into(&inp.xn, &pg, &pu, &pd, t, d, f, &mut gate, &mut up, &mut mlp);
    outs.push(mlp);
    let (attn, probs) =
        rk::mha_attention(&inp.qkv_flat, &inp.qkv_flat, &inp.qkv_flat, h, t, t, dh, 0, t, None);
    outs.push(attn);
    outs.push(probs);
    // paged kernels over hand-rolled slabs
    let slabs: Vec<&[f32]> = inp.slabs.iter().map(|s| s.as_slice()).collect();
    let v_base = h * inp.bsz * dh;
    let pprobs = rk::paged_attention_scores(
        &inp.q_paged,
        &slabs[..inp.len.div_ceil(inp.bsz)],
        0,
        h,
        inp.tq,
        dh,
        inp.bsz,
        inp.q_off,
        inp.len,
    );
    let pav = rk::paged_attn_av(
        &pprobs,
        &slabs[..inp.len.div_ceil(inp.bsz)],
        v_base,
        h,
        inp.tq,
        dh,
        inp.bsz,
        inp.q_off,
        inp.len,
    );
    outs.push(pprobs);
    outs.push(pav);
    let (ew, m, s) = rk::paged_relay_scores(
        &inp.q_relay,
        &slabs[..inp.prefix_len / inp.bsz],
        0,
        h,
        inp.n_relay,
        dh,
        inp.bsz,
        inp.prefix_len,
    );
    outs.push(ew);
    outs.push(m);
    outs.push(s);
    outs
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn kernels_bitwise_identical_at_every_pool_size() {
    check("kernel-pool-identity", 6, |rng| {
        let inp = KernelInputs::random(rng);
        // serial baseline: this test thread has no pool installed
        let serial = run_kernels(&inp);
        for threads in [1usize, 2, 3, 8] {
            let pool = Arc::new(Pool::new(threads, false));
            pool::install(&pool);
            let par = run_kernels(&inp);
            drop(pool); // expire the thread-local Weak
            chai::prop_assert!(
                serial.len() == par.len(),
                "kernel count mismatch at {threads} threads"
            );
            for (ki, (s, p)) in serial.iter().zip(&par).enumerate() {
                chai::prop_assert!(
                    bits(s) == bits(p),
                    "kernel #{ki} not bitwise identical at pool size {threads} \
                     (t={} d={} h={} dh={} f={})",
                    inp.t,
                    inp.d,
                    inp.h,
                    inp.dh,
                    inp.f
                );
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Backend level: fused multi-row decode vs one-row-at-a-time
// ---------------------------------------------------------------------------

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// One decode step over `store` (no relay descriptors): all rows in one
/// `decode_paged` call when `fused`, else one call per row.
fn step(
    be: &RefBackend,
    store: &mut PagedKv,
    seqs: &[u64],
    toks: &[i32],
    lens: &[usize],
    fused: bool,
) -> Result<Vec<Vec<f32>>, String> {
    for &s in seqs {
        store.ensure_append_slot(s).map_err(|e| e.to_string())?;
    }
    let rows: Vec<PagedDecodeRow> = seqs
        .iter()
        .zip(toks)
        .zip(lens)
        .map(|((&seq, &token), &pos)| PagedDecodeRow {
            seq,
            token,
            pos,
            clusters: None,
            relay: None,
        })
        .collect();
    let grab = |r: Result<chai::tensor::Tensor, anyhow::Error>| {
        r.map_err(|e| format!("{e:#}"))
            .and_then(|t| t.as_f32().map(|v| v.to_vec()).map_err(|e| e.to_string()))
    };
    if fused {
        be.decode_paged(&rows, store).into_iter().map(grab).collect()
    } else {
        rows.iter()
            .map(|r| {
                let one = [PagedDecodeRow {
                    seq: r.seq,
                    token: r.token,
                    pos: r.pos,
                    clusters: None,
                    relay: None,
                }];
                grab(be.decode_paged(&one, store).remove(0))
            })
            .collect()
    }
}

#[test]
fn fused_decode_matches_per_row_decode_bitwise() {
    check("fused-vs-per-row", 6, |rng| {
        let be = RefBackend::toy(rng.next_u64());
        let m = be.manifest().clone();
        let layout = KvLayout::from_manifest(&m, CacheKind::Mha);
        let bsz = 4usize;
        let n = rng.range(2, 6);
        let prompts: Vec<Vec<i32>> = (0..n)
            .map(|_| (0..rng.range(2, 11)).map(|_| rng.below(256) as i32).collect())
            .collect();
        let mut kv_f = PagedKv::new(bsz, 1 << 24);
        let mut kv_s = PagedKv::new(bsz, 1 << 24);
        for (i, p) in prompts.iter().enumerate() {
            let seq = (i + 1) as u64;
            for kv in [&mut kv_f, &mut kv_s] {
                kv.admit(seq, layout.clone(), "mha", true, p).map_err(|e| e.to_string())?;
                let start = kv.adopted_prefix_len(seq).map_err(|e| e.to_string())?;
                be.prefill_paged(seq, start, None, kv).map_err(|e| e.to_string())?;
                kv.commit_prefill(seq).map_err(|e| e.to_string())?;
            }
        }
        let seqs: Vec<u64> = (1..=n as u64).collect();
        let mut toks: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();
        let mut lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let steps = rng.range(2, 5);
        let fused_before =
            be.exec_counts.borrow().get("decode_fused_groups").copied().unwrap_or(0);
        for s in 0..steps {
            let fused = step(&be, &mut kv_f, &seqs, &toks, &lens, true)?;
            let serial = step(&be, &mut kv_s, &seqs, &toks, &lens, false)?;
            for (ri, (fl, sl)) in fused.iter().zip(&serial).enumerate() {
                chai::prop_assert!(
                    bits(fl) == bits(sl),
                    "step {s} row {ri}: fused logits not bitwise equal to per-row"
                );
            }
            for (ri, &seq) in seqs.iter().enumerate() {
                kv_f.append_committed(seq, toks[ri]).map_err(|e| e.to_string())?;
                kv_s.append_committed(seq, toks[ri]).map_err(|e| e.to_string())?;
                toks[ri] = argmax(&serial[ri]) as i32;
                lens[ri] += 1;
            }
        }
        let fused_after =
            be.exec_counts.borrow().get("decode_fused_groups").copied().unwrap_or(0);
        chai::prop_assert!(
            fused_after == fused_before + steps as u64,
            "expected {steps} fused group executions, got {}",
            fused_after - fused_before
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Engine level: stream identity across --threads
// ---------------------------------------------------------------------------

fn random_suffix(rng: &mut Rng, lo: usize, hi: usize) -> String {
    let n = rng.range(lo, hi);
    (0..n).map(|_| (rng.range(32, 127) as u8) as char).collect()
}

/// Build an engine with the given pool size on its own thread (so each
/// engine's pool install is isolated), run every session to completion
/// through fused ticks, and return the token streams.
fn streams_with_threads(
    seed: u64,
    threads: usize,
    specs: Vec<(String, Variant)>,
    max_new: usize,
) -> Result<Vec<Vec<i32>>, String> {
    std::thread::spawn(move || -> Result<Vec<Vec<i32>>, String> {
        let engine = Engine::load(ServingConfig {
            artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
            backend: "ref".into(),
            seed,
            threads,
            ..Default::default()
        })
        .map_err(|e| e.to_string())?;
        let mut sessions: Vec<Session> = specs
            .iter()
            .map(|(p, v)| engine.start_session(p, max_new, v))
            .collect::<anyhow::Result<_>>()
            .map_err(|e| e.to_string())?;
        loop {
            let mut refs: Vec<&mut Session> = sessions.iter_mut().filter(|s| !s.done).collect();
            if refs.is_empty() {
                break;
            }
            for o in engine.decode_tick(&mut refs) {
                o.map_err(|e| format!("decode_tick: {e:#}"))?;
            }
        }
        let streams = sessions.iter().map(|s| s.tokens.clone()).collect();
        for s in sessions {
            engine.finish_session(s);
        }
        Ok(streams)
    })
    .join()
    .map_err(|_| "engine thread panicked".to_string())?
}

#[test]
fn engine_streams_bit_identical_across_thread_counts() {
    check("threads-stream-identity", 3, |rng| {
        let seed = rng.next_u64();
        // relay group: >= 2 full 16-token blocks of shared prefix
        let shared = random_suffix(rng, 33, 42);
        let mut specs: Vec<(String, Variant)> = Vec::new();
        for _ in 0..rng.range(2, 4) {
            specs.push((format!("{shared}{}", random_suffix(rng, 0, 5)), Variant::Mha));
        }
        // independent MHA rows: the fused stacked path
        for _ in 0..rng.range(2, 4) {
            specs.push((random_suffix(rng, 3, 14), Variant::Mha));
        }
        // clustered rows: identical short prompts share a membership, so
        // they stack as one clustered fused group (too short to relay)
        let chai_prompt = random_suffix(rng, 3, 12);
        for _ in 0..rng.range(2, 4) {
            specs.push((chai_prompt.clone(), Variant::Chai));
        }
        let max_new = rng.range(4, 9);
        let base = streams_with_threads(seed, 1, specs.clone(), max_new)?;
        for threads in [2usize, 3, 8] {
            let got = streams_with_threads(seed, threads, specs.clone(), max_new)?;
            chai::prop_assert!(
                got == base,
                "streams diverge between --threads 1 and --threads {threads}"
            );
        }
        Ok(())
    });
}
