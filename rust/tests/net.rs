//! Integration tests for the event-driven net subsystem: the epoll
//! reactor transport (protocol parity with the threaded transport,
//! slow-reader isolation, multiplexed cancellation), the bounded
//! submission inbox's overloaded-shed contract, and the threaded
//! transport's idle-wakeup/stop-latency guarantees. Everything runs on
//! the pure-Rust reference backend (seeded toy model).

mod common;

use std::path::PathBuf;
use std::time::{Duration, Instant};

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::{Engine, Variant};
use chai::net::NetMode;
use chai::server::{Client, Server};
use chai::util::json::Json;

fn ref_cfg() -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("no-artifacts"),
        backend: "ref".into(),
        ..Default::default()
    }
}

/// Poll a predicate: gauges land at the end of the retiring tick,
/// slightly after the response goes out.
fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !f() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(f(), "not reached within 30s: {what}");
}

// ---------------------------------------------------------------------------
// Reactor transport: protocol parity with the threaded transport
// ---------------------------------------------------------------------------

/// The acceptance contract's core: a lockstep client observes
/// bit-identical behavior on both transports — same command replies,
/// same generation summaries, same frame-for-frame token streams.
#[cfg(target_os = "linux")]
#[test]
fn reactor_token_streams_are_bit_identical_to_threads() {
    let mut per_mode: Vec<(String, Vec<String>, String)> = Vec::new();
    for mode in [NetMode::Threads, NetMode::Reactor] {
        let handle = Coordinator::start(ref_cfg()).unwrap();
        let server =
            Server::start_with(handle.coordinator.clone(), "127.0.0.1:0", mode).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();

        assert!(client.ping().unwrap());
        let info = client.info().unwrap();
        assert_eq!(info.get("backend").unwrap().str().unwrap(), "ref");

        let summary = client.generate("the color of tom is", 8, "chai").unwrap();
        assert!(summary.opt("error").is_none(), "{summary:?}");
        let text = summary.get("text").unwrap().str().unwrap().to_string();

        let mut frames: Vec<String> = Vec::new();
        let done = client
            .generate_stream("tom keeps the hat", 8, "chai", |f| {
                frames.push(f.to_string());
            })
            .unwrap();
        assert!(done.opt("error").is_none(), "{done:?}");
        assert_eq!(
            frames.len(),
            done.get("n_generated").unwrap().usize().unwrap(),
            "one frame per decoded token"
        );
        let streamed: String = frames
            .iter()
            .map(|l| {
                let f = Json::parse(l).unwrap();
                f.get("text").unwrap().str().unwrap().to_string()
            })
            .collect();

        // the stats net section names the transport that served it
        let stats = client.stats().unwrap();
        let net = stats.get("net").unwrap();
        assert_eq!(net.get("net_transport").unwrap().str().unwrap(), mode.name());
        assert!(net.get("net_accepted_total").unwrap().usize().unwrap() >= 1);
        assert_eq!(net.get("net_lost_terminals").unwrap().usize().unwrap(), 0);

        per_mode.push((text, frames, streamed));
        server.stop();
        handle.shutdown();
    }
    let (t_text, t_frames, t_streamed) = &per_mode[0];
    let (r_text, r_frames, r_streamed) = &per_mode[1];
    assert_eq!(t_text, r_text, "summary text must match across transports");
    assert_eq!(t_frames, r_frames, "frame lines must be bit-identical");
    assert_eq!(t_streamed, r_streamed);
}

/// Reactor protocol error paths mirror the threaded transport: bad
/// JSON, unknown cmd, oversized prompt — error lines, live connection.
#[cfg(target_os = "linux")]
#[test]
fn reactor_protocol_errors_never_kill_the_connection() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server =
        Server::start_with(handle.coordinator.clone(), "127.0.0.1:0", NetMode::Reactor).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    client.send_raw("{not json at all\n").unwrap();
    let r = client.read_json().unwrap();
    assert!(r.opt("error").is_some(), "malformed JSON must error: {r:?}");

    let r = client
        .call(&Json::obj(vec![("cmd", Json::Str("selfdestruct".into()))]))
        .unwrap();
    assert!(r.get("error").unwrap().str().unwrap().contains("unknown cmd"), "{r:?}");

    let huge = "x".repeat(chai::server::MAX_PROMPT_BYTES + 1);
    let r = client.generate(&huge, 4, "chai").unwrap();
    assert!(r.get("error").unwrap().str().unwrap().contains("protocol limit"), "{r:?}");

    // ...and the connection still serves
    assert!(client.ping().unwrap());
    let ok = client.generate("the color of tom is", 4, "chai").unwrap();
    assert!(ok.opt("error").is_none(), "{ok:?}");

    server.stop();
    handle.shutdown();
}

/// Cross-connection cancellation through the reactor: the abort frees
/// the session (pool back to baseline) and the terminal cancelled line
/// reaches the streaming connection.
#[cfg(target_os = "linux")]
#[test]
fn reactor_cancel_mid_stream_restores_pool_baseline() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let coord = handle.coordinator.clone();
    let server = Server::start_with(coord.clone(), "127.0.0.1:0", NetMode::Reactor).unwrap();
    let addr = server.addr.to_string();

    // in-process hogs keep ticks busy so the abort lands mid-decode
    let hog_rxs: Vec<_> = (0..7)
        .map(|i| coord.submit(&format!("hog {i}"), 56, Variant::Chai))
        .collect();

    let mut stream_client = Client::connect(&addr).unwrap();
    let mut side_client = Client::connect(&addr).unwrap();
    stream_client
        .send(&Json::obj(vec![
            ("prompt", Json::Str("tom".into())),
            ("max_new", Json::Num(60.0)),
            ("variant", Json::Str("chai".into())),
            ("stream", Json::Bool(true)),
        ]))
        .unwrap();
    let first = stream_client.read_json().unwrap();
    assert!(first.opt("tok").is_some(), "expected a stream frame: {first:?}");
    let id = first.get("id").unwrap().usize().unwrap() as u64;

    let ack = side_client.cancel(id).unwrap();
    assert!(ack.get("ok").unwrap().boolean().unwrap());

    let terminal = loop {
        let j = stream_client.read_json().unwrap();
        if j.opt("tok").is_none() {
            break j;
        }
    };
    assert!(terminal.get("cancelled").unwrap().boolean().unwrap(), "{terminal:?}");
    assert!(terminal.get("n_generated").unwrap().usize().unwrap() < 60, "{terminal:?}");

    for rx in hog_rxs {
        let r = rx.recv_timeout(Duration::from_secs(600)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    wait_until("pool back to baseline", || {
        coord.metrics.gauge("sched_live") == 0.0
            && coord.metrics.gauge("kv_live_tables") == 0.0
            && coord.metrics.gauge("kv_live_blocks") == 0.0
    });
    server.stop();
    handle.shutdown();
}

/// Slow-reader isolation: a client that submits a stream and then stops
/// reading must not delay any other session. Its frames pile up in its
/// own connection's buffers; another client's requests complete
/// promptly, and the stalled client's stream is still intact when it
/// finally reads.
#[cfg(target_os = "linux")]
#[test]
fn reactor_slow_reader_never_delays_other_sessions() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server =
        Server::start_with(handle.coordinator.clone(), "127.0.0.1:0", NetMode::Reactor).unwrap();
    let addr = server.addr.to_string();

    // oracle for the fast client's text
    let mut oracle = Client::connect(&addr).unwrap();
    let want = oracle.generate("the color of tom is", 6, "chai").unwrap();
    assert!(want.opt("error").is_none(), "{want:?}");

    // the slow reader: submit a stream, then go silent without reading
    let mut slow = Client::connect(&addr).unwrap();
    slow.send(&Json::obj(vec![
        ("prompt", Json::Str("tom keeps the hat".into())),
        ("max_new", Json::Num(40.0)),
        ("variant", Json::Str("chai".into())),
        ("stream", Json::Bool(true)),
    ]))
    .unwrap();

    // meanwhile a fast client keeps getting served, bit-identically
    let mut fast = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        let r = fast.generate("the color of tom is", 6, "chai").unwrap();
        assert!(r.opt("error").is_none(), "{r:?}");
        assert_eq!(
            r.get("text").unwrap().str().unwrap(),
            want.get("text").unwrap().str().unwrap(),
            "fast client must be unaffected by the stalled reader"
        );
    }
    assert!(fast.ping().unwrap());

    // the stalled stream is complete and ordered once finally read
    let mut i = 0usize;
    let terminal = loop {
        let j = slow.read_json().unwrap();
        if j.opt("tok").is_none() {
            break j;
        }
        assert_eq!(j.get("i").unwrap().usize().unwrap(), i, "frames in order");
        i += 1;
    };
    assert!(terminal.opt("error").is_none(), "{terminal:?}");
    assert_eq!(i, terminal.get("n_generated").unwrap().usize().unwrap());

    server.stop();
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// --pin-cores: flag round-trip + pinned-CPU reporting (satellite)
// ---------------------------------------------------------------------------

/// `--pin-cores` round-trips through the config into both pinnable
/// threads: the engine tick thread reports its core via the
/// `pin_engine_cpu` gauge and the reactor via `net_pinned_cpu_plus1`,
/// both visible in one `{"cmd":"stats"}` reply — and serving results
/// are unaffected by pinning.
#[cfg(target_os = "linux")]
#[test]
fn pin_cores_round_trips_and_threads_report_pinned_cpus() {
    let cfg = ServingConfig { pin_cores: true, ..ref_cfg() };
    let handle = Coordinator::start(cfg).unwrap();
    let server =
        Server::start_with(handle.coordinator.clone(), "127.0.0.1:0", NetMode::Reactor).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    let r = client.generate("the color of tom is", 6, "chai").unwrap();
    assert!(r.opt("error").is_none(), "pinned serving must still work: {r:?}");

    let stats = client.stats().unwrap();
    let gauges = stats.get("gauges").unwrap();
    let engine_cpu = gauges.get("pin_engine_cpu").unwrap().usize().unwrap();
    assert!(engine_cpu < 1024, "engine tick thread must report its pinned CPU");
    let net = stats.get("net").unwrap();
    let reactor_cpu = net.get("net_pinned_cpu_plus1").unwrap().usize().unwrap();
    assert!(reactor_cpu >= 1, "reactor thread must report its pinned CPU");
    server.stop();
    handle.shutdown();

    // default-off: an unpinned stack reports neither
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server =
        Server::start_with(handle.coordinator.clone(), "127.0.0.1:0", NetMode::Reactor).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    assert!(client.ping().unwrap());
    let stats = client.stats().unwrap();
    assert!(
        stats.get("gauges").unwrap().opt("pin_engine_cpu").is_none(),
        "pinning must be off by default"
    );
    assert_eq!(
        stats.get("net").unwrap().get("net_pinned_cpu_plus1").unwrap().usize().unwrap(),
        0,
        "reactor must not pin by default"
    );
    server.stop();
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Partial line at EOF: identical rejection on both transports
// ---------------------------------------------------------------------------

/// A connection that closes with buffered bytes and no trailing newline
/// gets the same deterministic treatment on `--net threads` and `--net
/// reactor`: the partial line is REJECTED with the shared
/// `TRUNCATED_EOF_ERROR` line — never processed as a request — and the
/// connection is closed. A half-line could be a truncated prompt;
/// guessing at it would make the transports diverge on one byte stream.
#[test]
fn partial_line_at_eof_is_rejected_identically_on_both_transports() {
    use std::io::{Read, Write};
    use std::net::{Shutdown, TcpStream};

    let modes: Vec<NetMode> = {
        #[cfg(target_os = "linux")]
        {
            vec![NetMode::Threads, NetMode::Reactor]
        }
        #[cfg(not(target_os = "linux"))]
        {
            vec![NetMode::Threads]
        }
    };
    let mut replies: Vec<String> = Vec::new();
    for mode in modes {
        let handle = Coordinator::start(ref_cfg()).unwrap();
        let server =
            Server::start_with(handle.coordinator.clone(), "127.0.0.1:0", mode).unwrap();
        let addr = server.addr.to_string();

        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(b"{\"prompt\": \"the color of to").unwrap();
        raw.shutdown(Shutdown::Write).unwrap();
        let mut reply = String::new();
        raw.read_to_string(&mut reply).unwrap();
        let line = reply.lines().next().unwrap_or("").to_string();
        let j = Json::parse(&line).unwrap();
        assert_eq!(
            j.get("error").unwrap().str().unwrap(),
            chai::server::TRUNCATED_EOF_ERROR,
            "mode {}: {j:?}",
            mode.name()
        );
        assert_eq!(reply.lines().count(), 1, "error line then close, nothing else");

        // the rejection is visible in the transport's stats, and the
        // half-line was never admitted as a request
        let mut client = Client::connect(&addr).unwrap();
        let stats = client.stats().unwrap();
        let net = stats.get("net").unwrap();
        assert_eq!(net.get("net_truncated_eof").unwrap().usize().unwrap(), 1);
        assert_eq!(handle.coordinator.metrics.counter("completed"), 0);

        replies.push(line);
        server.stop();
        handle.shutdown();
    }
    // byte-identical error line across every transport that ran
    for w in replies.windows(2) {
        assert_eq!(w[0], w[1], "transports must agree on the rejection line");
    }
}

// ---------------------------------------------------------------------------
// Bounded inbox: overloaded shed (transport-independent)
// ---------------------------------------------------------------------------

/// Submissions that find the bounded inbox full are shed immediately
/// with a terminal `{"error": "overloaded"}` — and shedding admits
/// nothing, so after the backlog drains the pool is back at baseline.
#[test]
fn full_inbox_sheds_overloaded_and_restores_pool_baseline() {
    let inbox = 4usize;
    let cfg = ServingConfig { net_inbox: inbox, ..ref_cfg() };
    let load_cfg = cfg.clone();
    // hold the engine back so nothing drains while we overfill
    let handle = Coordinator::start_with(
        cfg,
        Box::new(move || {
            std::thread::sleep(Duration::from_millis(400));
            Engine::load(load_cfg)
        }),
    )
    .unwrap();
    let coord = handle.coordinator.clone();

    let rxs: Vec<_> = (0..inbox + 3)
        .map(|i| coord.submit(&format!("the color of tom {i}"), 4, Variant::Chai))
        .collect();

    let mut served = 0usize;
    let mut shed = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|e| panic!("request {i} hung: {e}"));
        match r.error.as_deref() {
            None => served += 1,
            Some("overloaded") => shed += 1,
            Some(other) => panic!("request {i}: unexpected error {other:?}"),
        }
    }
    assert_eq!(served, inbox, "ring capacity worth of requests must be served");
    assert_eq!(shed, 3, "overflow must shed with a terminal overloaded error");
    assert_eq!(coord.metrics.counter("net_shed_overloaded"), shed as u64);
    assert_eq!(coord.metrics.counter("completed"), served as u64);

    // shed requests admitted nothing: after the backlog drains, zero
    // live sessions, tables, or blocks remain anywhere
    wait_until("pool back to baseline", || {
        coord.metrics.gauge("sched_live") == 0.0
            && coord.metrics.gauge("sched_pending") == 0.0
            && coord.metrics.gauge("kv_live_tables") == 0.0
            && coord.metrics.gauge("kv_live_blocks") == 0.0
    });
    assert!(coord.metrics.gauge("net_inbox_hwm") >= inbox as f64);
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Threaded transport: idle wakeups + stop latency (satellite)
// ---------------------------------------------------------------------------

/// Idle connections must not spin the CPU: with the coarse idle-poll
/// interval, three silent clients over ~1.2 s cost a handful of
/// wakeups (the old 25 ms read timeout burned ~40/s per connection),
/// and `Server::stop` still returns promptly because blocked reads are
/// woken through the socket registry, not the timeout.
#[test]
fn threaded_idle_connections_wake_rarely_and_stop_is_fast() {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let net = server.net_stats();

    let idle: Vec<Client> = (0..3).map(|_| Client::connect(&addr).unwrap()).collect();
    wait_until("connections registered", || server.active_connections() == 3);
    let base = net.idle_wakeups.load(std::sync::atomic::Ordering::Relaxed);
    std::thread::sleep(Duration::from_millis(1200));
    let wakeups = net.idle_wakeups.load(std::sync::atomic::Ordering::Relaxed) - base;
    // 3 conns × 1.2 s at a 250 ms idle poll ≈ 15 wakeups; the old
    // 25 ms timeout would have produced ~144. Generous margin for CI.
    assert!(wakeups <= 40, "idle busy-wake regression: {wakeups} wakeups in 1.2s");

    let conns = server.conn_counter();
    let t0 = Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "stop must not hang on idle connections"
    );
    assert_eq!(
        conns.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "idle connection threads must observe stop and exit"
    );
    drop(idle);
    handle.shutdown();
}
