//! Forced-preemption determinism and overload scheduling.
//!
//! Invariants (artifact-free, seeded toy model, every `cargo test`):
//!
//! 1. Under `--preempt` with a pool far below the working set, every
//!    completed session's token stream is bit-identical to an
//!    uncontended run — for BOTH resume paths (swap-restore and
//!    recompute-via-suffix-prefill), for MHA and CHAI. The scheduler is
//!    driven directly (no threads), so the preemption schedule is
//!    fully deterministic.
//! 2. No request starves: over-capacity bursts drain with zero
//!    dropped/errored requests, preemptions actually fire, and the
//!    swap tier + block pool end empty.
//! 3. The coordinator/server stack surfaces the scheduler state
//!    (`{"cmd":"sched"}`: queue depths, preemption/swap counters).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver};

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::{Engine, Variant};
use chai::metrics::Metrics;
use chai::model::tokenizer;
use chai::runtime::Backend;
use chai::scheduler::{Request, Response, SchedPolicy, Scheduler};
use chai::server::{Client, Server};
use chai::util::proptest::check;
use chai::util::rng::Rng;
use chai::util::{now_ms, stats::percentile};

/// MHA-layout block bytes of the toy model at block_size 16 — used to
/// size pools in whole blocks without hardcoding model dims.
fn toy_block_bytes() -> usize {
    let m = chai::runtime::reference::RefBackend::toy(0).manifest().clone();
    chai::kv::paged::KvLayout::from_manifest(&m, chai::kv::CacheKind::Mha).block_bytes(16)
}

/// Preemption-enabled ref-backend config over a pool of `blocks` MHA
/// blocks. `swap_blocks == 0` forces every preemption down the
/// recompute-resume path; a roomy tier plus `recompute_max_tokens == 0`
/// forces swap-resume.
fn preempt_cfg(seed: u64, blocks: usize, swap_blocks: usize) -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
        backend: "ref".into(),
        seed,
        kv_capacity_bytes: blocks * toy_block_bytes(),
        preempt: true,
        starve_ticks: 1,
        swap_blocks,
        recompute_max_tokens: 0,
        ..Default::default()
    }
}

fn random_prompt(rng: &mut Rng) -> String {
    let n = rng.range(8, 24);
    (0..n).map(|_| (rng.range(32, 127) as u8) as char).collect()
}

fn make_req(
    id: u64,
    prompt: &str,
    max_new: usize,
    variant: Variant,
) -> (Request, Receiver<Response>) {
    let (tx, rx) = channel();
    (
        Request {
            id,
            prompt: prompt.into(),
            max_new,
            variant,
            submitted_ms: now_ms(),
            resp_tx: tx.into(),
            stream: None,
        },
        rx,
    )
}

/// Tick the scheduler to drain; panics if it fails to converge.
fn drain(sched: &mut Scheduler, engine: &Engine, metrics: &Metrics) {
    let mut ticks = 0u64;
    while !sched.is_idle() {
        sched.run_tick(engine, metrics);
        ticks += 1;
        assert!(ticks < 20_000, "scheduler failed to drain under preemption");
    }
}

#[test]
fn forced_preemption_streams_are_bit_identical() {
    check("preempt-determinism", 6, |rng| {
        let seed = rng.next_u64();
        let variant = if rng.below(2) == 0 { Variant::Mha } else { Variant::Chai };
        let n = rng.range(3, 5);
        let prompts: Vec<String> = (0..n).map(|_| random_prompt(rng)).collect();
        let max_new = rng.range(4, 8);

        // uncontended oracle: huge pool, no preemption, one at a time
        let oracle = Engine::load(ServingConfig {
            artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
            backend: "ref".into(),
            seed,
            ..Default::default()
        })
        .map_err(|e| e.to_string())?;
        let want: Vec<(String, usize)> = prompts
            .iter()
            .map(|p| {
                let g = oracle.generate(p, max_new, &variant).map_err(|e| e.to_string())?;
                let n_prompt = tokenizer::encode(p, true, false).len();
                Ok((g.text, g.tokens.len() - n_prompt))
            })
            .collect::<Result<_, String>>()?;

        // contended: a 3-block pool serializes the sessions and forces
        // preemption; swap-resume first, then recompute-resume
        for swap_blocks in [16usize, 0] {
            let cfg = preempt_cfg(seed, 3, swap_blocks);
            let engine = Engine::load(cfg.clone()).map_err(|e| e.to_string())?;
            let metrics = Metrics::new();
            let mut sched = Scheduler::new(SchedPolicy::from_config(&cfg));
            let rxs: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let (req, rx) = make_req(i as u64, p, max_new, variant.clone());
                    sched.submit(req);
                    rx
                })
                .collect();
            drain(&mut sched, &engine, &metrics);

            let mode = if swap_blocks > 0 { "swap" } else { "recompute" };
            if swap_blocks > 0 {
                chai::prop_assert!(
                    sched.stats.preempt_swap >= 1,
                    "[{mode}] contention must exercise a swap-out \
                     (swap {} / recompute {})",
                    sched.stats.preempt_swap,
                    sched.stats.preempt_recompute
                );
            } else {
                chai::prop_assert!(
                    sched.stats.preempt_recompute >= 1,
                    "[{mode}] contention must exercise a recompute preemption"
                );
                chai::prop_assert!(
                    sched.stats.preempt_swap == 0,
                    "[{mode}] a disabled tier can never swap"
                );
            }
            for (i, rx) in rxs.into_iter().enumerate() {
                let r = rx.try_recv().map_err(|_| format!("[{mode}] request {i} unanswered"))?;
                chai::prop_assert!(
                    r.error.is_none(),
                    "[{mode}] request {i} failed: {:?}",
                    r.error
                );
                chai::prop_assert!(
                    r.text == want[i].0 && r.n_generated == want[i].1,
                    "[{mode}] {} stream diverged under preemption for {:?}:\n  want ({:?}, {})\n  got  ({:?}, {})",
                    variant.name(),
                    prompts[i],
                    want[i].0,
                    want[i].1,
                    r.text,
                    r.n_generated
                );
            }
            chai::prop_assert!(
                metrics.gauge("kv_live_tables") == 0.0,
                "[{mode}] leaked live tables"
            );
            chai::prop_assert!(
                metrics.gauge("swap_used_bytes") == 0.0,
                "[{mode}] swap tier must drain"
            );
        }
        Ok(())
    });
}

#[test]
fn overload_burst_drains_with_zero_drops() {
    // an over-capacity burst (every session needs most of the pool)
    // with minimal starvation patience: nothing may be dropped, errored
    // or starved, and the preemption machinery must have fired
    let cfg = preempt_cfg(7, 3, 16);
    let engine = Engine::load(cfg.clone()).unwrap();
    let metrics = Metrics::new();
    let mut sched = Scheduler::new(SchedPolicy::from_config(&cfg));
    let mut rng = Rng::new(42);
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            let p = random_prompt(&mut rng);
            let (req, rx) = make_req(i, &p, 6, Variant::Chai);
            sched.submit(req);
            rx
        })
        .collect();
    drain(&mut sched, &engine, &metrics);
    let mut e2es = Vec::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.try_recv().expect("request answered");
        assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        assert_eq!(r.n_generated, 6, "request {i} ran to completion");
        e2es.push(r.e2e_ms);
    }
    assert!(
        sched.stats.preempt_swap + sched.stats.preempt_recompute >= 1,
        "an over-capacity burst must preempt"
    );
    // bound the whole lifetime (e2e), not just the first-admission wait:
    // queue_ms cannot see a session parked after a preemption
    assert!(percentile(&e2es, 99.0) < 120_000.0, "p99 e2e unbounded");
    assert_eq!(metrics.gauge("kv_live_tables"), 0.0);
    assert_eq!(metrics.gauge("sched_pending"), 0.0);
    assert_eq!(metrics.gauge("sched_preempted"), 0.0);
}

#[test]
fn coordinator_surfaces_sched_state_over_tcp() {
    // full-stack: coordinator + TCP server with preemption enabled;
    // the `sched` command exposes queue depths and swap/preempt state
    let cfg = ServingConfig { max_batch: 4, ..preempt_cfg(0, 4, 8) };
    let handle = Coordinator::start(cfg).unwrap();
    let coord = handle.coordinator.clone();
    let server = Server::start(coord.clone(), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    let rxs: Vec<_> = (0..4)
        .map(|i| coord.submit(&format!("a modest prompt number {i}"), 6, Variant::Chai))
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    // gauges land at the end of the retiring tick — responses go out
    // slightly earlier in the same tick, so poll instead of racing it
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while (coord.metrics.gauge("kv_capacity_bytes") == 0.0
        || coord.metrics.gauge("sched_live") != 0.0)
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let sched = client.sched().unwrap();
    for key in ["sched_pending", "sched_live", "sched_preempted", "swap_capacity_bytes"] {
        assert!(sched.opt(key).is_some(), "sched view missing {key}: {sched:?}");
    }
    assert_eq!(sched.get("sched_live").unwrap().usize().unwrap(), 0, "all retired");
    // the focused view must not leak unrelated metrics
    assert!(sched.opt("tokens").is_none());
    server.stop();
    handle.shutdown();
}
