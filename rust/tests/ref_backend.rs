//! Property tests over the pure-Rust reference backend. These encode
//! the two load-bearing invariants of the backend seam:
//!
//! 1. CHAI with K = H singleton clusters (identity membership) is
//!    **bit-for-bit** identical to dense MHA — on the scoring artifacts
//!    and on the prefill/decode serving artifacts.
//! 2. The paged KV data plane is invisible to the math: paged and
//!    `--no-paged` engines produce identical token streams for random
//!    prompts and seeds.
//!
//! Everything here runs without artifacts (seeded toy model), so
//! `cargo test` exercises it on every commit.

use std::path::PathBuf;

use chai::config::ServingConfig;
use chai::engine::{Engine, Variant};
use chai::runtime::reference::RefBackend;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::proptest::check;
use chai::util::rng::Rng;

/// Reference-backend config pinned to the toy model (a nonexistent
/// artifacts dir keeps the test deterministic even when `make
/// artifacts` has run).
fn toy_cfg(seed: u64) -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
        backend: "ref".into(),
        seed,
        ..Default::default()
    }
}

fn random_prompt(rng: &mut Rng) -> String {
    let n = rng.range(3, 32);
    (0..n).map(|_| (rng.range(32, 127) as u8) as char).collect()
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.as_f32().unwrap().iter().map(|x| x.to_bits()).collect()
}

/// Identity membership/reps tensors for L layers of H heads.
fn identity_clusters(l: usize, h: usize) -> (Tensor, Tensor) {
    let mem: Vec<i32> = (0..l).flat_map(|_| (0..h as i32)).collect();
    let reps = mem.clone();
    (Tensor::i32(vec![l, h], mem), Tensor::i32(vec![l, h], reps))
}

#[test]
fn singleton_cluster_logprob_equals_mha_bitwise() {
    check("singleton-logprob", 5, |rng| {
        let be = RefBackend::toy(rng.next_u64());
        let m = be.manifest().clone();
        let (l, h, t) = (m.model.n_layers, m.model.n_heads, m.logprob_bucket);
        assert!(m.uniform_k_sweep.contains(&h), "toy sweep must include k=H");
        let n = rng.range(2, t);
        let mut toks = vec![258i32; t]; // PAD
        for slot in toks.iter_mut().take(n) {
            *slot = rng.below(256) as i32;
        }
        let tokens = Tensor::i32(vec![t], toks);
        let len = Tensor::scalar_i32(n as i32);
        let mha = be
            .run("logprob_mha", &[In::Host(&tokens), In::Host(&len)])
            .map_err(|e| e.to_string())?[0]
            .to_tensor()
            .unwrap();
        let (mem, reps) = identity_clusters(l, h);
        let chai = be
            .run(
                &format!("logprob_chai_k{h}"),
                &[In::Host(&tokens), In::Host(&len), In::Host(&mem), In::Host(&reps)],
            )
            .map_err(|e| e.to_string())?[0]
            .to_tensor()
            .unwrap();
        chai::prop_assert!(
            bits(&mha) == bits(&chai),
            "chai k=H must be bit-for-bit MHA (seed case)"
        );
        Ok(())
    });
}

#[test]
fn singleton_cluster_serving_path_equals_mha_bitwise() {
    // prefill + a few decode steps: the clustered serving artifacts with
    // k_list = [H; L] and identity membership reproduce the MHA
    // artifacts exactly, caches included.
    let be = {
        let probe = RefBackend::toy(0);
        let m = probe.manifest();
        RefBackend::toy_custom(0, vec![m.model.n_heads; m.model.n_layers])
    };
    let m = be.manifest().clone();
    let (l, h, dh, t) = (m.model.n_layers, m.model.n_heads, m.model.head_dim, m.decode_buckets[0]);
    let n = 9usize;
    let mut toks = vec![258i32; t];
    for (i, b) in "prefix check".bytes().enumerate().take(n) {
        toks[i] = b as i32;
    }
    let tokens = Tensor::i32(vec![t], toks);
    let len = Tensor::scalar_i32(n as i32);
    let (mem, reps) = identity_clusters(l, h);

    let mha = be
        .run(&format!("prefill_mha_t{t}"), &[In::Host(&tokens), In::Host(&len)])
        .unwrap();
    let chai = be
        .run(
            &format!("prefill_chai_t{t}"),
            &[In::Host(&tokens), In::Host(&len), In::Host(&mem), In::Host(&reps)],
        )
        .unwrap();
    // logits identical
    let mha_logits = mha[0].to_tensor().unwrap();
    assert_eq!(bits(&mha_logits), bits(&chai[0].to_tensor().unwrap()));
    // the clustered K panels are exactly the per-layer slices of the
    // dense K cache, and V caches agree
    let kc = mha[1].to_tensor().unwrap();
    for i in 0..l {
        let krep = chai[1 + i].to_tensor().unwrap();
        assert_eq!(krep.shape, vec![h, t, dh]);
        assert_eq!(bits(&kc.index0(i)), bits(&krep), "layer {i} K");
    }
    let vc_mha = mha[2].to_tensor().unwrap();
    let vc_chai = chai[l + 1].to_tensor().unwrap();
    assert_eq!(bits(&vc_mha), bits(&vc_chai));

    // decode three tokens on both paths
    let (mut kc, mut vc) = (kc, vc_mha);
    let mut kreps: Vec<Tensor> = (0..l).map(|i| chai[1 + i].to_tensor().unwrap()).collect();
    let mut vcc = vc_chai;
    for (step, tok) in [65i32, 66, 67].into_iter().enumerate() {
        let pos = Tensor::scalar_i32((n + step) as i32);
        let tk = Tensor::scalar_i32(tok);
        let mo = be
            .run(
                &format!("decode_mha_t{t}"),
                &[In::Host(&tk), In::Host(&pos), In::Host(&kc), In::Host(&vc)],
            )
            .unwrap();
        let mut ins: Vec<In> = vec![In::Host(&tk), In::Host(&pos)];
        for kr in kreps.iter() {
            ins.push(In::Host(kr));
        }
        ins.push(In::Host(&vcc));
        ins.push(In::Host(&mem));
        ins.push(In::Host(&reps));
        let co = be.run(&format!("decode_chai_t{t}"), &ins).unwrap();
        let ml = mo[0].to_tensor().unwrap();
        let cl = co[0].to_tensor().unwrap();
        assert_eq!(bits(&ml), bits(&cl), "decode step {step} logits");
        kc = mo[1].to_tensor().unwrap();
        vc = mo[2].to_tensor().unwrap();
        kreps = (0..l).map(|i| co[1 + i].to_tensor().unwrap()).collect();
        vcc = co[l + 1].to_tensor().unwrap();
        for i in 0..l {
            assert_eq!(bits(&kc.index0(i)), bits(&kreps[i]), "step {step} layer {i} K");
        }
    }
}

#[test]
fn paged_and_contiguous_decode_streams_agree() {
    check("paged-vs-contiguous", 6, |rng| {
        let seed = rng.next_u64();
        let prompt = random_prompt(rng);
        let max_new = rng.range(3, 9);
        let variant = if rng.below(2) == 0 { Variant::Mha } else { Variant::Chai };
        let paged = Engine::load(ServingConfig { paged_kv: true, ..toy_cfg(seed) })
            .map_err(|e| e.to_string())?;
        let contiguous = Engine::load(ServingConfig { paged_kv: false, ..toy_cfg(seed) })
            .map_err(|e| e.to_string())?;
        let a = paged
            .generate(&prompt, max_new, &variant)
            .map_err(|e| e.to_string())?;
        let b = contiguous
            .generate(&prompt, max_new, &variant)
            .map_err(|e| e.to_string())?;
        chai::prop_assert!(
            a.tokens == b.tokens,
            "{} prompt {prompt:?}: paged {:?} vs contiguous {:?}",
            variant.name(),
            a.tokens,
            b.tokens
        );
        Ok(())
    });
}

#[test]
fn generation_is_deterministic_per_seed() {
    let e1 = Engine::load(toy_cfg(42)).unwrap();
    let e2 = Engine::load(toy_cfg(42)).unwrap();
    let g1 = e1.generate("the color of tom is", 8, &Variant::Chai).unwrap();
    let g2 = e2.generate("the color of tom is", 8, &Variant::Chai).unwrap();
    assert_eq!(g1.tokens, g2.tokens);
    // a different weight seed steers generation elsewhere eventually;
    // at minimum the engines must load and serve
    let e3 = Engine::load(toy_cfg(7)).unwrap();
    let g3 = e3.generate("the color of tom is", 8, &Variant::Chai).unwrap();
    assert_eq!(g3.tokens.len(), g1.tokens.len());
}
