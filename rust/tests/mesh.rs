//! Integration tests for the replica mesh: the location-transparent
//! process transport (`chai replica` children behind the router),
//! graceful drain with live-session migration, and the crash contract —
//! a `kill -9`'d replica loses ZERO accepted requests; survivors finish
//! them with exactly-once, bit-identical token streams (greedy decode).
//! Everything runs on the pure-Rust reference backend (seeded toy
//! model), with the replica child binary pointed at the freshly-built
//! `chai` via `CARGO_BIN_EXE_chai`.

use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::Variant;
use chai::router::{Frontend, Router};
use chai::scheduler::{Response, StreamFrame, SubmitOpts};

fn ref_cfg() -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("no-artifacts"),
        backend: "ref".into(),
        ..Default::default()
    }
}

#[cfg(target_os = "linux")]
fn process_cfg(replicas: usize) -> ServingConfig {
    ServingConfig {
        replicas,
        transport: "process".into(),
        replica_cmd: Some(PathBuf::from(env!("CARGO_BIN_EXE_chai"))),
        // fast suspect->dead escalation keeps the failover tests quick
        probe_ms: 50,
        probe_suspect: 3,
        ..ref_cfg()
    }
}

/// Greedy-decode oracle: each prompt generated alone on a plain
/// single-engine coordinator. The mesh must reproduce these bytes no
/// matter where (or how many times) it places the request.
fn oracle_texts(prompts: &[String], max_new: usize) -> Vec<String> {
    let handle = Coordinator::start(ref_cfg()).unwrap();
    let texts = prompts
        .iter()
        .map(|p| {
            let r = handle
                .coordinator
                .submit(p, max_new, Variant::Chai)
                .recv_timeout(Duration::from_secs(600))
                .unwrap();
            assert!(r.error.is_none(), "oracle: {:?}", r.error);
            r.text
        })
        .collect();
    handle.shutdown();
    texts
}

/// One in-flight streaming request: its frame channel and terminal rx.
struct Stream {
    frames: Receiver<StreamFrame>,
    resp: Receiver<Response>,
}

fn submit_stream(router: &Router, prompt: &str, max_new: usize) -> Stream {
    let (tx, frames) = std::sync::mpsc::channel();
    let (_, resp) = router.submit_opts(SubmitOpts {
        stream: Some(tx.into()),
        ..SubmitOpts::new(prompt, max_new, Variant::Chai)
    });
    Stream { frames, resp }
}

/// Wait for the terminal, then require the stream to be complete and
/// exactly-once: frame indexes 0..n-1 with no gap or duplicate (across
/// however many replicas served it), concatenating to `want`.
fn assert_stream_exact(label: &str, s: Stream, want: &str) {
    let r = s.resp.recv_timeout(Duration::from_secs(600)).unwrap();
    assert!(r.error.is_none(), "[{label}] {:?}", r.error);
    assert!(!r.cancelled, "[{label}] spurious cancel");
    assert_eq!(r.text, want, "[{label}] terminal text must match the oracle");
    // frames are forwarded before their terminal (single reader, wire
    // order), so after recv'ing the terminal the channel holds them all
    let got: Vec<StreamFrame> = s.frames.try_iter().collect();
    assert_eq!(got.len(), r.n_generated, "[{label}] one frame per token");
    let mut cat = String::new();
    for (i, f) in got.iter().enumerate() {
        assert_eq!(f.index, i, "[{label}] frames contiguous, exactly once");
        cat.push_str(&f.text);
    }
    assert_eq!(cat, want, "[{label}] frames must concatenate to the oracle text");
}

// ---------------------------------------------------------------------------
// Process transport: placement transparency
// ---------------------------------------------------------------------------

/// Separate `chai replica` processes behind the router serve the exact
/// request streams the in-process replicas do — location transparency
/// down to the bytes, for both plain and streaming requests.
#[cfg(target_os = "linux")]
#[test]
fn process_replicas_match_the_single_engine_oracle() {
    let prompts: Vec<String> =
        (0..4).map(|i| format!("the color of tom number {i}")).collect();
    let want = oracle_texts(&prompts, 6);

    let handle = Router::start(process_cfg(2)).unwrap();
    let router = handle.router.clone();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| router.submit_opts(SubmitOpts::new(p, 6, Variant::Chai)).1)
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(600)).unwrap();
        assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        assert_eq!(r.text, want[i], "request {i} text must match the oracle");
    }
    // both children actually served traffic
    assert!(router.metrics.counter("router_routed_replica_0") >= 1);
    assert!(router.metrics.counter("router_routed_replica_1") >= 1);
    assert_eq!(router.counter_sum("completed"), 4);

    // streaming crosses the process boundary frame-for-frame
    let s = submit_stream(&router, &prompts[0], 6);
    assert_stream_exact("process stream", s, &want[0]);

    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Graceful drain: live sessions migrate mid-generation
// ---------------------------------------------------------------------------

/// Draining a process replica mid-decode freezes its live sessions into
/// the mesh wire form, survivors adopt them, and every client stream
/// stays complete and bit-identical — the continuation decodes on a
/// DIFFERENT process than the prefix did.
#[cfg(target_os = "linux")]
#[test]
fn process_drain_migrates_live_sessions_mid_decode() {
    let prompts: Vec<String> =
        (0..2).map(|i| format!("tom keeps the hat in box {i}")).collect();
    let want = oracle_texts(&prompts, 40);

    let handle = Router::start(process_cfg(2)).unwrap();
    let router = handle.router.clone();
    // round-robin on a fresh router: request 0 -> replica 0, 1 -> 1
    let streams: Vec<Stream> =
        prompts.iter().map(|p| submit_stream(&router, p, 40)).collect();
    // three observed frames prove request 0 is admitted and mid-decode
    let mut seen = 0usize;
    for _ in 0..3 {
        let f = streams[0].frames.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(f.index, seen);
        seen += 1;
    }

    let moved = router.drain_replica(0).unwrap();
    assert!(moved >= 1, "the mid-decode session must migrate");
    assert_eq!(router.metrics.counter("router_migrated_sessions") as usize, moved);
    assert_eq!(router.metrics.gauge("router_replicas_alive") as usize, 1);

    // the drained stream finishes on the survivor; the frames the
    // client already holds are never re-sent (indexes stay contiguous)
    for (i, s) in streams.into_iter().enumerate() {
        assert_stream_exact(&format!("drained stream {i}"), s, &want[i]);
    }
    assert!(router.drain_replica(0).is_err(), "second drain must refuse");
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// The crash contract: kill -9 loses nothing
// ---------------------------------------------------------------------------

/// The acceptance drill: 4 process replicas, a burst of streaming
/// requests, SIGKILL one replica mid-decode. The supervisor declares it
/// dead, every request it had accepted is requeued on survivors at its
/// recorded stream offset, and EVERY accepted request completes with an
/// exactly-once, oracle-identical stream. Zero losses, zero duplicates.
#[cfg(target_os = "linux")]
#[test]
fn sigkill_mid_decode_loses_zero_accepted_requests() {
    let prompts: Vec<String> =
        (0..8).map(|i| format!("a long tale of tom number {i}")).collect();
    let want = oracle_texts(&prompts, 40);

    let handle = Router::start(process_cfg(4)).unwrap();
    let router = handle.router.clone();
    let streams: Vec<Stream> =
        prompts.iter().map(|p| submit_stream(&router, p, 40)).collect();
    // wait until decode is demonstrably underway...
    let f = streams[0].frames.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(f.index, 0);
    // ...then SIGKILL the replica holding the most accepted requests
    let victim = (0..router.replica_count())
        .max_by_key(|i| router.transport(*i).inflight())
        .unwrap();
    let in_flight = router.transport(victim).inflight();
    assert!(in_flight >= 1, "victim must hold accepted requests when killed");
    router.transport(victim).kill_hard().unwrap();

    // every accepted request still completes, bit-identically, with
    // contiguous frame indexes across the replica generations
    for (i, s) in streams.into_iter().enumerate() {
        assert_stream_exact(&format!("stream {i}"), s, &want[i]);
    }
    assert_eq!(router.metrics.counter("router_replica_deaths"), 1);
    assert_eq!(router.metrics.gauge("router_replicas_alive") as usize, 3);
    assert!(
        router.metrics.counter("router_requeued") >= 1,
        "the victim's accepted requests must have been requeued"
    );

    // the mesh keeps serving new work after the death
    let s = submit_stream(&router, &prompts[0], 6);
    let r = s.resp.recv_timeout(Duration::from_secs(600)).unwrap();
    assert!(r.error.is_none(), "post-crash submit: {:?}", r.error);
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Local transport: the same drain semantics without serialization
// ---------------------------------------------------------------------------

/// Draining an in-process replica migrates a mid-decode streaming
/// session over the zero-copy path ([`chai::router::MeshSession`] stays
/// in memory) with the identical client-visible contract: contiguous
/// frames, oracle-identical text.
#[test]
fn local_drain_keeps_streams_contiguous_and_bit_identical() {
    let prompt = "tom keeps the hat in the box".to_string();
    let want = oracle_texts(&[prompt.clone()], 40);

    let cfg = ServingConfig { replicas: 2, ..ref_cfg() };
    let handle = Router::start(cfg).unwrap();
    let router = handle.router.clone();
    // fresh rr rotation: the first submit lands on replica 0
    let s = submit_stream(&router, &prompt, 40);
    for i in 0..3 {
        let f = s.frames.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(f.index, i, "frames in order before the drain");
    }
    let moved = router.drain_replica(0).unwrap();
    assert!(moved >= 1, "the live streaming session must migrate");
    assert_stream_exact("local drained stream", s, &want[0]);
    handle.shutdown();
}

/// A router with every replica gone fails new submissions with a
/// terminal error instead of hanging the client.
#[test]
fn empty_fleet_fails_requests_with_terminal_errors() {
    let cfg = ServingConfig { replicas: 1, ..ref_cfg() };
    let handle = Router::start(cfg).unwrap();
    let router = handle.router.clone();
    let moved = router.drain_replica(0).unwrap();
    assert_eq!(moved, 0);
    let (_, rx) = router.submit_opts(SubmitOpts::new("tom", 4, Variant::Chai));
    let deadline = Instant::now() + Duration::from_secs(30);
    let r = loop {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(r) => break r,
            Err(_) if Instant::now() < deadline => continue,
            Err(e) => panic!("request into an empty fleet hung: {e}"),
        }
    };
    assert!(r.error.is_some(), "must fail, not hang: {r:?}");
    handle.shutdown();
}
