//! Integration tests over the full stack: backend + engine + clustering +
//! coordinator + server.
//!
//! Every test here runs **unconditionally** against the pure-Rust
//! reference backend (seeded toy model — no artifacts required), so
//! `cargo test` exercises the complete serving stack on a fresh
//! checkout. When `make artifacts` has produced the AOT set, the same
//! tests ALSO run against the XLA backend (and a few extra checks that
//! need the trained model — fact recall, eval accuracy — stay
//! artifact-gated).

mod common;

use std::path::{Path, PathBuf};

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::{Engine, Variant};
use chai::eval;
use chai::model::tokenizer;
use chai::server::{Client, Server};
use chai::util::json::Json;
use common::{artifacts, stack_cfgs};

fn engines() -> Vec<Engine> {
    stack_cfgs().into_iter().map(|c| Engine::load(c).expect("engine load")).collect()
}

/// XLA engine on the trained artifacts, when present.
fn xla_engine() -> Option<Engine> {
    artifacts().map(|d| Engine::from_dir(&d).expect("engine load"))
}

#[test]
fn ref_backend_always_serves() {
    // the root guarantee of the backend seam: a fresh checkout with no
    // artifacts still brings the full stack up
    let cfg = ServingConfig {
        artifacts_dir: PathBuf::from("no-artifacts"),
        backend: "ref".into(),
        ..Default::default()
    };
    let e = Engine::load(cfg).unwrap();
    assert_eq!(e.backend_name(), "ref");
    let g = e.generate("hello", 4, &Variant::Chai).unwrap();
    assert!(g.tokens.len() > 2);
}

#[test]
fn auto_backend_falls_back_to_ref_without_artifacts() {
    let cfg = ServingConfig {
        artifacts_dir: PathBuf::from("no-artifacts"),
        backend: "auto".into(),
        ..Default::default()
    };
    let e = Engine::load(cfg).unwrap();
    assert_eq!(e.backend_name(), "ref");
    // an explicit xla request without artifacts must error, not fall back
    let cfg = ServingConfig {
        artifacts_dir: PathBuf::from("no-artifacts"),
        backend: "xla".into(),
        ..Default::default()
    };
    assert!(Engine::load(cfg).is_err());
    // unknown backends are rejected
    let cfg = ServingConfig { backend: "tpu".into(), ..Default::default() };
    assert!(Engine::load(cfg).is_err());
}

#[test]
fn online_membership_respects_k_list() {
    for e in engines() {
        let m = e.manifest().clone();
        let tokens = tokenizer::encode("tom keeps the hat in the box .", true, false);
        let (ms, probe_ms, cluster_ms) = e.online_membership(&tokens).unwrap();
        assert_eq!(ms.len(), m.model.n_layers);
        for (l, mem) in ms.iter().enumerate() {
            assert_eq!(mem.membership.len(), m.model.n_heads);
            assert_eq!(mem.reps.len(), m.k_list[l]);
            assert!(mem.membership.iter().all(|x| *x < m.k_list[l]));
            for (j, &r) in mem.reps.iter().enumerate() {
                assert_eq!(mem.membership[r], j, "rep not in own cluster");
            }
        }
        assert!(probe_ms > 0.0 && cluster_ms > 0.0);
    }
}

#[test]
fn membership_is_context_dependent_but_stable_per_context() {
    for e in engines() {
        let t1 = tokenizer::encode("the color of tom is red", true, false);
        let (a, _, _) = e.online_membership(&t1).unwrap();
        let (b, _, _) = e.online_membership(&t1).unwrap();
        // deterministic per context
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.membership, y.membership);
        }
    }
}

#[test]
fn generation_variants_produce_text() {
    for e in engines() {
        for v in [Variant::Mha, Variant::Chai, Variant::ChaiStatic] {
            let g = e.generate("the color of tom is", 8, &v).unwrap();
            assert!(g.tokens.len() > 5, "{}/{}: no tokens", e.backend_name(), v.name());
            assert!(g.timing.ttft_ms > 0.0);
            assert!(!g.timing.decode_ms.is_empty());
            if v == Variant::Chai {
                assert!(g.timing.probe_ms > 0.0, "chai must include probe time");
            }
        }
    }
}

#[test]
fn scoring_path_all_variants_finite() {
    for e in engines() {
        let m = e.manifest().clone();
        let tokens = tokenizer::encode("question : does tom eat rice ? answer : yes", true, false);
        let mut variants = vec![
            Variant::Mha,
            Variant::Chai,
            Variant::ChaiStatic,
            Variant::ChaiQkv,
            Variant::Spatten,
        ];
        for p in &m.dejavu_sparsities {
            variants.push(Variant::Dejavu(*p));
        }
        for k in &m.uniform_k_sweep {
            variants.push(Variant::UniformK { k: *k, random: true });
            variants.push(Variant::UniformK { k: *k, random: false });
        }
        for v in variants {
            let lg = e.logits(&tokens, &v).unwrap();
            assert_eq!(lg.shape, vec![m.logprob_bucket, m.model.vocab_size]);
            let s = e.score_choice(&lg, &tokens, tokens.len() - 2);
            assert!(s.is_finite(), "{}: non-finite score", v.name());
            assert!(s <= 0.0, "{}: logprob must be <= 0, got {s}", v.name());
        }
    }
}

#[test]
fn coordinator_serves_concurrent_requests() {
    for base in stack_cfgs() {
        let cfg = ServingConfig { max_batch: 4, ..base };
        let handle = Coordinator::start(cfg).unwrap();
        let coord = handle.coordinator.clone();
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                let variant = if i % 2 == 0 { Variant::Chai } else { Variant::Mha };
                coord.submit("the color of tom is", 4, variant)
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert!(resp.n_generated >= 1);
            assert!(resp.e2e_ms > 0.0);
        }
        assert_eq!(coord.metrics.counter("completed"), 5);
        assert_eq!(coord.metrics.counter("submitted"), 5);
        assert!(coord.metrics.info("backend").is_some());
        handle.shutdown();
    }
}

#[test]
fn server_roundtrip_over_tcp() {
    for base in stack_cfgs() {
        let backend = base.backend.clone();
        let cfg = ServingConfig { max_batch: 2, ..base };
        let handle = Coordinator::start(cfg).unwrap();
        let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();

        let mut client = Client::connect(&addr).unwrap();
        assert!(client.ping().unwrap());
        let resp = client.generate("the color of tom is", 4, "chai").unwrap();
        assert!(resp.opt("error").is_none(), "{resp:?}");
        assert!(resp.get("ttft_ms").unwrap().num().unwrap() > 0.0);
        assert!(resp.get("n_generated").unwrap().usize().unwrap() >= 1);

        // malformed input yields an error object, not a dropped connection
        let bad = client.call(&Json::obj(vec![("nope", Json::Bool(true))])).unwrap();
        assert!(bad.opt("error").is_some());

        let stats = client.stats().unwrap();
        assert!(stats.get("counters").unwrap().get("completed").unwrap().usize().unwrap() >= 1);
        // the server reports which backend it serves with
        let info = client.info().unwrap();
        assert_eq!(info.get("backend").unwrap().str().unwrap(), backend);

        drop(client);
        server.stop();
        handle.shutdown();
    }
}

#[test]
fn chai_identity_membership_matches_mha_logits() {
    // k=H uniform artifact with identity membership reproduces dense
    // MHA — the end-to-end analogue of the kernel-level invariant, run
    // against whichever k=H artifact the manifest provides. (The
    // bit-for-bit ref-backend version lives in tests/ref_backend.rs;
    // XLA fuses differently, so this one compares to a tolerance.)
    use chai::runtime::{Backend, In};
    use chai::tensor::Tensor;
    for e in engines() {
        let m = e.manifest().clone();
        let (l, h, t) = (m.model.n_layers, m.model.n_heads, m.logprob_bucket);
        if !m.uniform_k_sweep.contains(&h) {
            continue; // no k=H artifact lowered for this model
        }
        let prompt_tokens = tokenizer::encode("the color of tom is red", true, false);
        let mut padded = vec![258i32; t];
        padded[..prompt_tokens.len()].copy_from_slice(&prompt_tokens);
        let tokens = Tensor::i32(vec![t], padded);
        let len = Tensor::scalar_i32(prompt_tokens.len() as i32);
        let ident: Vec<i32> = (0..l).flat_map(|_| 0..h as i32).collect();
        let mem = Tensor::i32(vec![l, h], ident.clone());
        let reps = Tensor::i32(vec![l, h], ident);
        let mha = e.rt.run("logprob_mha", &[In::Host(&tokens), In::Host(&len)]).unwrap()[0]
            .to_tensor()
            .unwrap();
        let chai = e
            .rt
            .run(
                &format!("logprob_chai_k{h}"),
                &[In::Host(&tokens), In::Host(&len), In::Host(&mem), In::Host(&reps)],
            )
            .unwrap()[0]
            .to_tensor()
            .unwrap();
        let (av, bv) = (mha.as_f32().unwrap(), chai.as_f32().unwrap());
        assert_eq!(av.len(), bv.len());
        for (i, (a, b)) in av.iter().zip(bv).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4,
                "[{}] logit {i}: mha {a} vs chai(k=H,identity) {b}",
                e.backend_name()
            );
        }
    }
}

#[test]
fn trained_model_recalls_facts_under_chai() {
    // The quickstart claim: CHAI preserves the model's knowledge.
    // Needs the trained weights, so this stays artifact-gated.
    let Some(e) = xla_engine() else { return };
    let g = e.generate("the color of tom is", 6, &Variant::Chai).unwrap();
    assert!(
        g.text.contains("red"),
        "expected fact recall, got {:?}",
        g.text
    );
}

#[test]
fn eval_chai_close_to_mha_on_subset() {
    // Accuracy-shape check (full Tables 1-3 run in the bench): CHAI's
    // accuracy on a slice of boolq-syn must be within 25 points of MHA
    // (paper: max 3.2% deviation at full scale). Needs the trained
    // model + eval suites, so artifact-gated.
    let Some(e) = xla_engine() else { return };
    let dir = artifacts().unwrap();
    let suite = eval::load_suite(&dir, "boolq-syn").unwrap();
    let mha = eval::accuracy(&e, &suite, &Variant::Mha, Some(12)).unwrap();
    let chai = eval::accuracy(&e, &suite, &Variant::Chai, Some(12)).unwrap();
    assert!(mha > 50.0, "MHA should beat chance on boolq-syn, got {mha}");
    assert!((mha - chai).abs() <= 25.0, "chai {chai} too far from mha {mha}");
}

#[test]
fn ref_backend_interprets_real_artifacts_when_present() {
    // When artifacts exist, the ref backend loads the REAL trained
    // weights (no HLO needed) — the correctness oracle for the XLA path.
    let Some(dir) = artifacts() else { return };
    let cfg = ServingConfig { artifacts_dir: dir, backend: "ref".into(), ..Default::default() };
    let e = Engine::load(cfg).unwrap();
    assert_eq!(e.backend_name(), "ref");
    let g = e.generate("the color of tom is", 6, &Variant::Chai).unwrap();
    assert!(
        g.text.contains("red"),
        "ref backend on trained weights must recall facts too, got {:?}",
        g.text
    );
}

#[test]
fn opt_variant_artifacts_load_if_present() {
    // Table 1 uses the OPT-like model; verify its artifact set works.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts-opt");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let e = Engine::from_dir(&dir).unwrap();
    assert_eq!(e.manifest().model.name, "tiny-opt-chai");
    let tokens = tokenizer::encode("the color of tom is red", true, false);
    for v in [Variant::Mha, Variant::Chai, Variant::Dejavu(50)] {
        let lg = e.logits(&tokens, &v).unwrap();
        assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()), "{}", v.name());
    }
}
