//! Integration tests over the full stack: runtime + engine + clustering +
//! coordinator + server against the real AOT artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (not failed)
//! when the artifacts are absent so `cargo test` stays meaningful in a
//! fresh checkout.

use std::path::{Path, PathBuf};

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::{Engine, Variant};
use chai::eval;
use chai::model::tokenizer;
use chai::server::{Client, Server};
use chai::util::json::Json;

fn artifacts() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

fn engine() -> Option<Engine> {
    artifacts().map(|d| Engine::from_dir(&d).expect("engine load"))
}

#[test]
fn chai_identity_membership_matches_mha_logits() {
    // k=H uniform artifact with identity membership reproduces dense MHA:
    // the end-to-end rust-side analogue of the kernel-level invariant.
    let Some(e) = engine() else { return };
    let m = e.manifest();
    let h = m.model.n_heads;
    let Some(&k) = m.uniform_k_sweep.iter().max() else { return };
    if k != h {
        // identity check requires a k=H artifact; fall back to agreement
        // between chai-static and mha on argmax tokens instead.
        let tokens = tokenizer::encode("the color of tom is", true, false);
        let a = e.logits(&tokens, &Variant::Mha).unwrap();
        let b = e.logits(&tokens, &Variant::ChaiStatic).unwrap();
        let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        assert_eq!(av.len(), bv.len());
        return;
    }
}

#[test]
fn online_membership_respects_k_list() {
    let Some(e) = engine() else { return };
    let m = e.manifest().clone();
    let tokens = tokenizer::encode("tom keeps the hat in the box .", true, false);
    let (ms, probe_ms, cluster_ms) = e.online_membership(&tokens).unwrap();
    assert_eq!(ms.len(), m.model.n_layers);
    for (l, mem) in ms.iter().enumerate() {
        assert_eq!(mem.membership.len(), m.model.n_heads);
        assert_eq!(mem.reps.len(), m.k_list[l]);
        assert!(mem.membership.iter().all(|x| *x < m.k_list[l]));
        for (j, &r) in mem.reps.iter().enumerate() {
            assert_eq!(mem.membership[r], j, "rep not in own cluster");
        }
    }
    assert!(probe_ms > 0.0 && cluster_ms > 0.0);
}

#[test]
fn membership_is_context_dependent_but_stable_per_context() {
    let Some(e) = engine() else { return };
    let t1 = tokenizer::encode("the color of tom is red", true, false);
    let (a, _, _) = e.online_membership(&t1).unwrap();
    let (b, _, _) = e.online_membership(&t1).unwrap();
    // deterministic per context
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.membership, y.membership);
    }
}

#[test]
fn generation_variants_produce_text() {
    let Some(e) = engine() else { return };
    for v in [Variant::Mha, Variant::Chai, Variant::ChaiStatic] {
        let g = e.generate("the color of tom is", 8, &v).unwrap();
        assert!(g.tokens.len() > 5, "{}: no tokens", v.name());
        assert!(g.timing.ttft_ms > 0.0);
        assert!(!g.timing.decode_ms.is_empty());
        if v == Variant::Chai {
            assert!(g.timing.probe_ms > 0.0, "chai must include probe time");
        }
    }
}

#[test]
fn trained_model_recalls_facts_under_chai() {
    // The quickstart claim: CHAI preserves the model's knowledge.
    let Some(e) = engine() else { return };
    let g = e.generate("the color of tom is", 6, &Variant::Chai).unwrap();
    assert!(
        g.text.contains("red"),
        "expected fact recall, got {:?}",
        g.text
    );
}

#[test]
fn scoring_path_all_variants_finite() {
    let Some(e) = engine() else { return };
    let m = e.manifest().clone();
    let tokens = tokenizer::encode("question : does tom eat rice ? answer : yes", true, false);
    let mut variants = vec![
        Variant::Mha,
        Variant::Chai,
        Variant::ChaiStatic,
        Variant::ChaiQkv,
        Variant::Spatten,
    ];
    for p in &m.dejavu_sparsities {
        variants.push(Variant::Dejavu(*p));
    }
    for k in &m.uniform_k_sweep {
        variants.push(Variant::UniformK { k: *k, random: true });
        variants.push(Variant::UniformK { k: *k, random: false });
    }
    for v in variants {
        let lg = e.logits(&tokens, &v).unwrap();
        assert_eq!(lg.shape, vec![m.logprob_bucket, m.model.vocab_size]);
        let s = e.score_choice(&lg, &tokens, tokens.len() - 2);
        assert!(s.is_finite(), "{}: non-finite score", v.name());
        assert!(s <= 0.0, "{}: logprob must be <= 0, got {s}", v.name());
    }
}

#[test]
fn eval_chai_close_to_mha_on_subset() {
    // Accuracy-shape check (full Tables 1-3 run in the bench): CHAI's
    // accuracy on a slice of boolq-syn must be within 25 points of MHA
    // (paper: max 3.2% deviation at full scale).
    let Some(e) = engine() else { return };
    let dir = artifacts().unwrap();
    let suite = eval::load_suite(&dir, "boolq-syn").unwrap();
    let mha = eval::accuracy(&e, &suite, &Variant::Mha, Some(12)).unwrap();
    let chai = eval::accuracy(&e, &suite, &Variant::Chai, Some(12)).unwrap();
    assert!(mha > 50.0, "MHA should beat chance on boolq-syn, got {mha}");
    assert!((mha - chai).abs() <= 25.0, "chai {chai} too far from mha {mha}");
}

#[test]
fn coordinator_serves_concurrent_requests() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServingConfig { artifacts_dir: dir, max_batch: 4, ..Default::default() };
    let handle = Coordinator::start(cfg).unwrap();
    let coord = handle.coordinator.clone();
    let rxs: Vec<_> = (0..5)
        .map(|i| {
            let variant = if i % 2 == 0 { Variant::Chai } else { Variant::Mha };
            coord.submit("the color of tom is", 4, variant)
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.n_generated >= 1);
        assert!(resp.e2e_ms > 0.0);
    }
    assert_eq!(coord.metrics.counter("completed"), 5);
    assert_eq!(coord.metrics.counter("submitted"), 5);
    handle.shutdown();
}

#[test]
fn server_roundtrip_over_tcp() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServingConfig { artifacts_dir: dir, max_batch: 2, ..Default::default() };
    let handle = Coordinator::start(cfg).unwrap();
    let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    let mut client = Client::connect(&addr).unwrap();
    assert!(client.ping().unwrap());
    let resp = client.generate("the color of tom is", 4, "chai").unwrap();
    assert!(resp.opt("error").is_none(), "{resp:?}");
    assert!(resp.get("ttft_ms").unwrap().num().unwrap() > 0.0);
    assert!(resp.get("n_generated").unwrap().usize().unwrap() >= 1);

    // malformed input yields an error object, not a dropped connection
    let bad = client.call(&Json::obj(vec![("nope", Json::Bool(true))])).unwrap();
    assert!(bad.opt("error").is_some());

    let stats = client.stats().unwrap();
    assert!(stats.get("counters").unwrap().get("completed").unwrap().usize().unwrap() >= 1);

    drop(client);
    server.stop();
    handle.shutdown();
}

#[test]
fn opt_variant_artifacts_load_if_present() {
    // Table 1 uses the OPT-like model; verify its artifact set works.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts-opt");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let e = Engine::from_dir(&dir).unwrap();
    assert_eq!(e.manifest().model.name, "tiny-opt-chai");
    let tokens = tokenizer::encode("the color of tom is red", true, false);
    for v in [Variant::Mha, Variant::Chai, Variant::Dejavu(50)] {
        let lg = e.logits(&tokens, &v).unwrap();
        assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()), "{}", v.name());
    }
}
