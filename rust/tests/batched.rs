//! Property tests for the block-table-native batched decode path.
//!
//! Invariants (all artifact-free, seeded toy model, `cargo test` on
//! every commit):
//!
//! 1. `Engine::decode_tick` over N concurrent paged sessions produces
//!    token streams identical to N sequential single-session decodes on
//!    a `--no-batched-decode` engine (bucket gather/scatter path) — for
//!    MHA and CHAI, with shared prompt prefixes in the mix so prefix
//!    adoption, prefill skipping, and CoW all fire mid-batch.
//! 2. Prefix-suffix prefill equals full prefill: a session whose prompt
//!    blocks were adopted (prefill compute skipped) generates the same
//!    stream as the first session that computed them from scratch.
//! 3. The batched hot path performs ZERO bucket-shaped K,V
//!    gather/scatter copies (asserted via the block-pool copy counters),
//!    while the sequential path pays them every step.

use std::path::PathBuf;

use chai::config::ServingConfig;
use chai::engine::{Engine, Session, Variant};
use chai::util::proptest::check;
use chai::util::rng::Rng;

/// Ref-backend config pinned to the toy model; `batched` selects the
/// fused block-native path vs the legacy bucket path.
fn toy_cfg(seed: u64, batched: bool) -> ServingConfig {
    ServingConfig {
        artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
        backend: "ref".into(),
        seed,
        batched_decode: batched,
        ..Default::default()
    }
}

fn random_prompt(rng: &mut Rng) -> String {
    let n = rng.range(3, 24);
    (0..n).map(|_| (rng.range(32, 127) as u8) as char).collect()
}

/// Drive a set of live sessions to completion through fused ticks.
fn run_ticks(engine: &Engine, sessions: &mut [Session]) -> Result<(), String> {
    loop {
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        let outcomes = engine.decode_tick(&mut refs);
        drop(refs);
        for o in &outcomes {
            if let Err(e) = o {
                return Err(format!("decode_tick: {e:#}"));
            }
        }
        if sessions.iter().all(|s| s.done) {
            return Ok(());
        }
    }
}

#[test]
fn batched_ticks_equal_sequential_decodes() {
    check("batched-vs-sequential", 6, |rng| {
        let seed = rng.next_u64();
        let variant = if rng.below(2) == 0 { Variant::Mha } else { Variant::Chai };
        let n = rng.range(3, 6);
        // a shared prompt appears at least twice so adoption + prefill
        // skipping + CoW happen inside the batch
        let shared = random_prompt(rng);
        let prompts: Vec<String> = (0..n)
            .map(|i| if i % 2 == 0 { shared.clone() } else { random_prompt(rng) })
            .collect();
        let max_new = rng.range(3, 8);

        // batched: one engine, all sessions live at once, fused ticks
        let batched = Engine::load(toy_cfg(seed, true)).map_err(|e| e.to_string())?;
        let mut sessions: Vec<Session> = prompts
            .iter()
            .map(|p| batched.start_session(p, max_new, &variant))
            .collect::<anyhow::Result<_>>()
            .map_err(|e| e.to_string())?;
        run_ticks(&batched, &mut sessions)?;
        let snap = batched.paged_snapshot().unwrap();
        chai::prop_assert!(
            snap.stats.decode_gather_copies == 0 && snap.stats.decode_scatter_copies == 0,
            "batched path must not touch bucket-shaped caches (gathers {}, scatters {})",
            snap.stats.decode_gather_copies,
            snap.stats.decode_scatter_copies
        );
        let streams: Vec<Vec<i32>> = sessions.iter().map(|s| s.tokens.clone()).collect();
        for s in sessions {
            batched.finish_session(s);
        }

        // sequential oracle: fresh engine, bucket gather/scatter path,
        // one request at a time
        let sequential = Engine::load(toy_cfg(seed, false)).map_err(|e| e.to_string())?;
        for (p, want) in prompts.iter().zip(&streams) {
            let g = sequential
                .generate(p, max_new, &variant)
                .map_err(|e| e.to_string())?;
            chai::prop_assert!(
                &g.tokens == want,
                "{} prompt {p:?}: batched {want:?} vs sequential {:?}",
                variant.name(),
                g.tokens
            );
        }
        let snap = sequential.paged_snapshot().unwrap();
        chai::prop_assert!(
            snap.stats.decode_gather_copies > 0,
            "sequential bucket path must be counting its gathers"
        );
        Ok(())
    });
}

#[test]
fn prefix_suffix_prefill_equals_full_prefill() {
    check("prefill-skip", 6, |rng| {
        let seed = rng.next_u64();
        let variant = if rng.below(2) == 0 { Variant::Mha } else { Variant::Chai };
        let max_new = rng.range(3, 8);
        let e = Engine::load(toy_cfg(seed, true)).map_err(|e| e.to_string())?;
        let contiguous = Engine::load(ServingConfig { paged_kv: false, ..toy_cfg(seed, true) })
            .map_err(|e| e.to_string())?;

        // (a) concurrent identical prompts: the 2nd session adopts the
        // whole prompt — full blocks AND the partial tail — before any
        // decode, so its prefill runs the logits-only pass (start == len)
        let prompt = random_prompt(rng);
        let mut s1 = e
            .start_session(&prompt, max_new, &variant)
            .map_err(|e| e.to_string())?;
        let before = e.paged_snapshot().unwrap().stats.prefill_skipped_tokens;
        let mut s2 = e
            .start_session(&prompt, max_new, &variant)
            .map_err(|e| e.to_string())?;
        let after = e.paged_snapshot().unwrap().stats.prefill_skipped_tokens;
        chai::prop_assert!(
            after > before,
            "adopting session must skip prefill compute ({before} -> {after})"
        );
        chai::prop_assert!(
            s1.tokens == s2.tokens,
            "first sampled token must agree: {:?} vs {:?}",
            s1.tokens,
            s2.tokens
        );
        {
            let mut both = [&mut s1, &mut s2];
            loop {
                for o in e.decode_tick(&mut both) {
                    o.map_err(|e| format!("{e:#}"))?;
                }
                if both.iter().all(|s| s.done) {
                    break;
                }
            }
        }
        chai::prop_assert!(
            s1.tokens == s2.tokens,
            "{} prompt {prompt:?}: scratch {:?} vs prefix-skipped {:?}",
            variant.name(),
            s1.tokens,
            s2.tokens
        );
        let stream = s1.tokens.clone();
        e.finish_session(s1);
        e.finish_session(s2);
        let oracle = contiguous
            .generate(&prompt, max_new, &variant)
            .map_err(|e| e.to_string())?;
        chai::prop_assert!(
            oracle.tokens == stream,
            "paged-native vs contiguous: {stream:?} vs {:?}",
            oracle.tokens
        );

        // (b) adoption from a *finished* request: a prompt spanning a
        // full block keeps its leading blocks published through decode
        // (only the mutated tail is unpublished), so the suffix-only
        // prefill path runs with 0 < start < len
        let long: String =
            (0..rng.range(18, 30)).map(|_| (rng.range(32, 127) as u8) as char).collect();
        let g1 = e.generate(&long, max_new, &variant).map_err(|e| e.to_string())?;
        let before = e.paged_snapshot().unwrap().stats.prefill_skipped_tokens;
        let g2 = e.generate(&long, max_new, &variant).map_err(|e| e.to_string())?;
        let after = e.paged_snapshot().unwrap().stats.prefill_skipped_tokens;
        chai::prop_assert!(
            after >= before + 16,
            "leading full prompt block must be skipped ({before} -> {after})"
        );
        chai::prop_assert!(
            g1.tokens == g2.tokens,
            "{} long prompt: scratch {:?} vs prefix-skipped {:?}",
            variant.name(),
            g1.tokens,
            g2.tokens
        );
        Ok(())
    });
}

#[test]
fn mixed_variant_tick_groups_by_kind() {
    // MHA and CHAI sessions live in the same tick: decode_tick groups
    // them into (at most) one fused call per variant and every stream
    // still matches its solo run
    let e = Engine::load(toy_cfg(11, true)).unwrap();
    let prompts = ["the color of tom is", "tom keeps the hat in the box"];
    let mut sessions: Vec<Session> = vec![
        e.start_session(prompts[0], 5, &Variant::Mha).unwrap(),
        e.start_session(prompts[1], 5, &Variant::Chai).unwrap(),
        e.start_session(prompts[0], 5, &Variant::Chai).unwrap(),
    ];
    run_ticks(&e, &mut sessions).unwrap();
    let streams: Vec<Vec<i32>> = sessions.iter().map(|s| s.tokens.clone()).collect();
    for s in sessions {
        e.finish_session(s);
    }
    let snap = e.paged_snapshot().unwrap();
    assert_eq!(snap.stats.decode_gather_copies, 0);
    assert_eq!(snap.stats.decode_scatter_copies, 0);
    assert_eq!(snap.live_tables, 0, "all sessions released");

    let solo = Engine::load(toy_cfg(11, true)).unwrap();
    for (i, (p, v)) in [
        (prompts[0], Variant::Mha),
        (prompts[1], Variant::Chai),
        (prompts[0], Variant::Chai),
    ]
    .iter()
    .enumerate()
    {
        let g = solo.generate(p, 5, v).unwrap();
        assert_eq!(g.tokens, streams[i], "session {i} ({})", v.name());
    }
}
