//! Integration tests for the observability layer (`chai::obs`): span
//! tracing woven through router → coordinator → scheduler → engine,
//! flight-recorder ring semantics, Chrome trace-event dump
//! well-formedness, trace-id propagation across the process transport —
//! including the SIGKILL requeue drill, where one request's timeline
//! must stitch across the replica it died on and the survivor that
//! finished it — and the ≤-zero-cost contract: token streams are
//! bit-identical with observability on and off.
//!
//! The obs enable flag is process-global (`--no-obs`), so every test
//! here serializes on one lock and restores the enabled state.

use std::collections::HashSet;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::Variant;
use chai::obs::{self, SpanEvent, SpanKind, TraceRing};
use chai::router::{Frontend, Router};
use chai::scheduler::{Response, StreamFrame, SubmitOpts};
use chai::util::json::Json;
use std::sync::mpsc::Receiver;

/// Tests toggle the process-global obs flag; run them one at a time.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn ref_cfg() -> ServingConfig {
    ServingConfig {
        artifacts_dir: std::path::PathBuf::from("no-artifacts"),
        backend: "ref".into(),
        ..Default::default()
    }
}

struct Stream {
    frames: Receiver<StreamFrame>,
    resp: Receiver<Response>,
}

fn submit_stream<F: Frontend>(api: &F, prompt: &str, max_new: usize) -> Stream {
    let (tx, frames) = std::sync::mpsc::channel();
    let (_, resp) = api.submit_opts(SubmitOpts {
        stream: Some(tx.into()),
        ..SubmitOpts::new(prompt, max_new, Variant::Chai)
    });
    Stream { frames, resp }
}

fn finish(label: &str, s: Stream) -> (String, Vec<String>) {
    let r = s.resp.recv_timeout(Duration::from_secs(600)).unwrap();
    assert!(r.error.is_none(), "[{label}] {:?}", r.error);
    assert!(!r.cancelled, "[{label}] spurious cancel");
    let frames: Vec<StreamFrame> = s.frames.try_iter().collect();
    assert_eq!(frames.len(), r.n_generated, "[{label}] one frame per token");
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.index, i, "[{label}] contiguous frames");
    }
    (r.text, frames.into_iter().map(|f| f.text).collect())
}

/// Every nonzero trace id mentioned anywhere in a dump.
fn trace_ids(dump: &Json) -> HashSet<u64> {
    dump.get("traceEvents")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|ev| ev.get("args").unwrap().get("trace").unwrap().num().unwrap() as u64)
        .filter(|&t| t != 0)
        .collect()
}

/// Structural check on one Chrome trace event; returns
/// `(name, pid, trace)`.
fn check_event(ev: &Json) -> (String, u64, u64) {
    let name = ev.get("name").unwrap().str().unwrap().to_string();
    let known: HashSet<&str> = SpanKind::ALL.iter().map(|k| k.as_str()).collect();
    assert!(known.contains(name.as_str()), "unknown span name {name:?}");
    assert_eq!(ev.get("ph").unwrap().str().unwrap(), "X", "complete events only — no orphan B/E");
    assert_eq!(ev.get("cat").unwrap().str().unwrap(), "obs");
    assert!(ev.get("ts").unwrap().num().unwrap() > 0.0, "unix-epoch µs timestamp");
    assert!(ev.get("dur").unwrap().num().unwrap() >= 0.0);
    let pid = ev.get("pid").unwrap().num().unwrap() as u64;
    assert!(pid > 0);
    ev.get("tid").unwrap().num().unwrap();
    let trace = ev.get("args").unwrap().get("trace").unwrap().num().unwrap() as u64;
    (name, pid, trace)
}

// ---------------------------------------------------------------------------
// Flight recorder ring: bounded, oldest-dropped
// ---------------------------------------------------------------------------

/// Overflowing the recorder drops the OLDEST spans: the ring's job is
/// to hold the most recent history at a crash (the opposite of the
/// shed-newest `net::ring` queues).
#[test]
fn flight_recorder_overflow_drops_oldest_not_newest() {
    let r = TraceRing::new(16);
    for i in 0..50u64 {
        r.push(SpanEvent { trace: i, kind: 0, start_ms: i as f64, dur_ms: 1.0 });
    }
    assert_eq!(r.recorded(), 50);
    assert_eq!(r.overwritten(), 50 - r.capacity());
    let kept: Vec<u64> = r.snapshot().iter().map(|e| e.trace).collect();
    let newest: Vec<u64> = (50 - r.capacity() as u64..50).collect();
    assert_eq!(kept, newest, "newest spans retained, oldest overwritten");
    // idempotent: draining the dump must not consume the recorder
    assert_eq!(r.snapshot().len(), kept.len());
}

// ---------------------------------------------------------------------------
// Trace dump well-formedness (single process)
// ---------------------------------------------------------------------------

/// A served coordinator's `{"cmd":"trace"}` dump is well-formed Chrome
/// trace JSON: complete-only events with the span taxonomy, request
/// spans attributed to nonzero trace ids, per-tick spans to trace 0.
#[test]
fn trace_dump_is_well_formed_chrome_trace_json() {
    let _g = obs_lock();
    let handle = Coordinator::start(ref_cfg()).unwrap();
    assert!(obs::enabled(), "obs defaults to on");
    let streams: Vec<Stream> = (0..2)
        .map(|i| submit_stream(&handle.coordinator, &format!("the color of tom {i}"), 8))
        .collect();
    for (i, s) in streams.into_iter().enumerate() {
        finish(&format!("req {i}"), s);
    }

    let dump = Frontend::trace_json(&handle.coordinator);
    // survives the wire: render and reparse
    let dump = Json::parse(&dump.to_string()).unwrap();
    assert!(dump.get("pid").unwrap().num().unwrap() > 0.0);
    assert!(dump.get("spans_dropped").unwrap().num().unwrap() >= 0.0);
    let events = dump.get("traceEvents").unwrap().arr().unwrap();
    assert!(!events.is_empty());
    let mut names = HashSet::new();
    let mut zero_trace = 0usize;
    let mut req_traces = HashSet::new();
    for ev in events {
        let (name, _, trace) = check_event(ev);
        if trace == 0 {
            zero_trace += 1;
        } else if name == "queue" {
            req_traces.insert(trace);
        }
        names.insert(name);
    }
    for want in ["queue", "prefill", "decode_tick", "frame_write"] {
        assert!(names.contains(want), "span kind {want:?} missing from {names:?}");
    }
    assert!(zero_trace > 0, "per-tick spans carry trace 0");
    assert!(req_traces.len() >= 2, "each request minted its own trace id");

    // the frame path feeds the per-request latency histograms, with raw
    // buckets exposed for cross-replica merging
    let stats = Frontend::stats_json(&handle.coordinator);
    let lat = stats.get("latency").unwrap();
    for key in ["obs_ttft_ms", "obs_queue_wait_ms", "obs_decode_tick_ms"] {
        let h = lat.get(key).unwrap_or_else(|_| panic!("{key} missing"));
        assert!(h.get("count").unwrap().num().unwrap() > 0.0, "{key} observed");
        assert!(!h.get("buckets").unwrap().arr().unwrap().is_empty(), "{key} raw buckets");
    }
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// Cross-process propagation + the SIGKILL stitch
// ---------------------------------------------------------------------------

/// The acceptance drill: process replicas behind the router, SIGKILL
/// one mid-decode. Every request keeps ONE trace id across admission,
/// the wire, and the crash requeue — the merged dump holds each
/// request's spans from both sides of the process boundary, and the
/// requeued request's timeline continues under its original id on the
/// survivor (no second timeline, no orphan spans).
#[cfg(target_os = "linux")]
#[test]
fn sigkill_requeue_yields_one_stitched_timeline_per_request() {
    let _g = obs_lock();
    let n_req = 6usize;
    let cfg = ServingConfig {
        replicas: 3,
        transport: "process".into(),
        replica_cmd: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_chai"))),
        probe_ms: 50,
        probe_suspect: 3,
        ..ref_cfg()
    };
    let trace_out = std::env::temp_dir().join(format!("chai-obs-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&trace_out);
    let cfg = ServingConfig { trace_out: Some(trace_out.clone()), ..cfg };
    // rings persist for the process lifetime, so earlier tests in this
    // binary may have left spans behind — only traces minted from here
    // on belong to this drill
    let preexisting: HashSet<u64> = trace_ids(&obs::dump_json());
    let handle = Router::start(cfg).unwrap();
    let router = handle.router.clone();

    let streams: Vec<Stream> = (0..n_req)
        .map(|i| submit_stream(&router, &format!("a long tale of tom number {i}"), 40))
        .collect();
    // decode demonstrably underway, then SIGKILL the busiest replica
    let f = streams[0].frames.recv_timeout(Duration::from_secs(60)).unwrap();
    assert_eq!(f.index, 0);
    let victim = (0..router.replica_count())
        .max_by_key(|i| router.transport(*i).inflight())
        .unwrap();
    assert!(router.transport(victim).inflight() >= 1);
    router.transport(victim).kill_hard().unwrap();

    for (i, s) in streams.into_iter().enumerate() {
        finish(&format!("stream {i}"), s);
    }
    assert_eq!(router.metrics.counter("router_replica_deaths"), 1);
    assert!(router.metrics.counter("router_requeued") >= 1);

    // one merged dump: the router's own rings + each live child's
    let dump = Json::parse(&Frontend::trace_json(&router).to_string()).unwrap();
    let parent_pid = dump.get("pid").unwrap().num().unwrap() as u64;
    let events = dump.get("traceEvents").unwrap().arr().unwrap();
    let mut pids = HashSet::new();
    let mut child_queue_traces: HashSet<u64> = HashSet::new();
    let mut parent_frame_traces: HashSet<u64> = HashSet::new();
    for ev in events {
        let (name, pid, trace) = check_event(ev);
        pids.insert(pid);
        if trace == 0 || preexisting.contains(&trace) {
            continue;
        }
        if pid != parent_pid && name == "queue" {
            child_queue_traces.insert(trace);
        }
        if pid == parent_pid && name == "frame_write" {
            parent_frame_traces.insert(trace);
        }
    }
    assert!(pids.len() >= 2, "spans from the router AND its children: {pids:?}");
    // every request was admitted (queue span) in a surviving child
    // under exactly its router-minted trace id — a requeue that minted
    // a fresh id would show up as an extra timeline here
    assert_eq!(
        child_queue_traces.len(),
        n_req,
        "one trace id per request, stable across the SIGKILL requeue"
    );
    // the parent's frame_write spans stitch onto those same timelines
    assert!(!parent_frame_traces.is_empty());
    for t in &parent_frame_traces {
        assert!(
            child_queue_traces.contains(t),
            "parent span with trace {t} has no child-side timeline (orphan)"
        );
    }
    // replica death triggered a --trace-out flight-recorder dump
    let on_disk = Json::parse_file(&trace_out).expect("--trace-out written on replica death");
    assert!(!on_disk.get("traceEvents").unwrap().arr().unwrap().is_empty());

    // router-merged stats carry the frame-path histograms bucket-wise
    let stats = Frontend::stats_json(&router);
    let lat = stats.get("latency").unwrap();
    let ttft = lat.get("obs_ttft_ms").expect("merged obs_ttft_ms");
    assert!(
        ttft.get("count").unwrap().num().unwrap() >= n_req as f64,
        "every streamed request recorded a TTFT"
    );
    assert!(lat.get("obs_tbt_ms").is_ok(), "inter-token histogram merged");
    handle.shutdown();
    let _ = std::fs::remove_file(&trace_out);
}

// ---------------------------------------------------------------------------
// The overhead contract's correctness half: obs never touches tokens
// ---------------------------------------------------------------------------

/// `--no-obs` must change nothing but the recording: token streams are
/// bit-identical with observability on and off (obs only reads clocks).
#[test]
fn streams_are_bit_identical_with_obs_on_and_off() {
    let _g = obs_lock();
    let prompt = "tom keeps the hat in the box";

    let on = Coordinator::start(ref_cfg()).unwrap();
    assert!(obs::enabled());
    let (text_on, frames_on) = finish("obs on", submit_stream(&on.coordinator, prompt, 24));
    on.shutdown();

    let off = Coordinator::start(ServingConfig { obs: false, ..ref_cfg() }).unwrap();
    assert!(!obs::enabled(), "--no-obs must gate the recorder globally");
    let (text_off, frames_off) = finish("obs off", submit_stream(&off.coordinator, prompt, 24));
    off.shutdown();
    obs::set_enabled(true);

    assert_eq!(text_on, text_off, "terminal text must be bit-identical");
    assert_eq!(frames_on, frames_off, "per-token frames must be bit-identical");
}
