//! Integration tests for the paged KV subsystem.
//!
//! The store-level tests run everywhere (the block pool / prefix index /
//! CoW machinery needs no artifacts), and since the pure-Rust reference
//! backend landed so do the engine/coordinator-level tests: they drive
//! real requests with a shared prompt prefix through the serving stack
//! on the ref backend unconditionally, plus the XLA backend when
//! `rust/artifacts` exists.

mod common;

use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::engine::{Engine, Variant};
use chai::kv::paged::{paged_cache_bytes, KvLayout, PagedKv};
use chai::kv::CacheKind;
use common::{artifacts, stack_cfgs};

fn layout() -> KvLayout {
    // CHAI-shaped: K panels hold only each layer's k_l representative heads
    KvLayout { n_layers: 4, n_heads: 8, head_dim: 16, k_heads: vec![3, 4, 5, 8] }
}

#[test]
fn shared_prefix_blocks_are_adopted_and_cow_splits_on_divergence() {
    let block = 16;
    let mut kv = PagedKv::new(block, 64 * 1024 * 1024);
    // 2 full blocks + a 6-token partial tail
    let prompt: Vec<i32> = (0..38).collect();

    kv.admit(1, layout(), "chai", true, &prompt).unwrap();
    kv.commit_prefill(1).unwrap();
    let solo_bytes = kv.snapshot().used_bytes;

    // identical prompt: adopts every block, zero extra bytes
    let report = kv.admit(2, layout(), "chai", true, &prompt).unwrap();
    kv.commit_prefill(2).unwrap();
    assert_eq!(report.adopted_full, 2, "both full prompt blocks adopted");
    assert!(report.adopted_partial, "partial tail adopted");
    assert_eq!(kv.snapshot().used_bytes, solo_bytes, "sharing must be free");
    assert!(kv.stats.prefix_hit_rate() > 0.0);

    // divergence: each sequence decodes its own continuation; the shared
    // partial tail must copy-on-write exactly once
    kv.ensure_append_slot(2).unwrap();
    kv.append_committed(2, 1001).unwrap();
    assert_eq!(kv.stats.cow_copies, 1, "CoW on first divergent append");
    kv.ensure_append_slot(1).unwrap();
    kv.append_committed(1, 2002).unwrap();
    assert_eq!(kv.stats.cow_copies, 1, "sole owner appends in place");

    // both sequences see their own tail
    assert_eq!(kv.table(1).unwrap().tokens[38], 2002);
    assert_eq!(kv.table(2).unwrap().tokens[38], 1001);
    kv.check_consistency().unwrap();

    // release: no leak — remaining bytes are all evictable cache
    kv.release(1).unwrap();
    kv.release(2).unwrap();
    let snap = kv.snapshot();
    assert_eq!(snap.live_tables, 0);
    assert_eq!(snap.used_bytes, snap.cached_bytes, "only cached blocks remain");
    kv.drop_cached();
    assert_eq!(kv.snapshot().used_bytes, 0, "pool drains to zero");
    assert_eq!(kv.snapshot().indexed_prefixes, 0, "index drains with the pool");
}

#[test]
fn third_request_reuses_cache_after_owners_finished() {
    let mut kv = PagedKv::new(16, 64 * 1024 * 1024);
    let prompt: Vec<i32> = (500..540).collect();
    kv.admit(1, layout(), "chai", true, &prompt).unwrap();
    kv.commit_prefill(1).unwrap();
    kv.release(1).unwrap();
    // blocks are cached, not lost: a later identical prompt adopts them
    let report = kv.admit(2, layout(), "chai", true, &prompt).unwrap();
    assert_eq!(report.adopted_full, 2);
    assert!(report.adopted_partial);
    kv.release(2).unwrap();
    kv.check_consistency().unwrap();
}

#[test]
fn chai_paged_footprint_stays_below_mha() {
    // Fig.-11 invariant at block granularity, artifact-free
    let chai = layout();
    let mha = KvLayout { k_heads: vec![8; 4], ..layout() };
    for t in [1usize, 16, 100, 1000] {
        let blocks = (t + 15) / 16;
        assert!(
            blocks * chai.block_bytes(16) < blocks * mha.block_bytes(16),
            "t={t}"
        );
    }
    // and against the real manifest when artifacts exist
    if let Some(dir) = artifacts() {
        let m = chai::config::Manifest::load(&dir).unwrap();
        for t in [128usize, 512, 2048] {
            let c = paged_cache_bytes(CacheKind::Chai, &m, t, 16);
            let d = paged_cache_bytes(CacheKind::Mha, &m, t, 16);
            assert!(c < d, "t={t}: paged chai {c} !< paged mha {d}");
        }
    }
}

#[test]
fn engine_sessions_share_prefix_and_cow_on_divergence() {
    // Deterministic (single-threaded) version of the sharing story,
    // driven through the engine session API on every backend: the 2nd
    // identical prompt adopts the 1st's blocks (incl. the partial tail,
    // 20 tokens = 1 full block of 16 + 4), and the shared tail
    // copy-on-writes exactly once when the sessions diverge at decode.
    for cfg in stack_cfgs() {
        let cfg = ServingConfig { kv_block_size: 16, ..cfg };
        let e = Engine::load(cfg).unwrap();
        let prompt = "the color of tom is";
        let mut s1 = e.start_session(prompt, 4, &Variant::Chai).unwrap();
        let mut s2 = e.start_session(prompt, 4, &Variant::Chai).unwrap();
        let snap = e.paged_snapshot().unwrap();
        assert_eq!(
            snap.stats.prefix_hit_blocks, 2,
            "[{}] full block + partial tail adopted",
            e.backend_name()
        );
        assert!(snap.stats.prefix_hit_rate() > 0.0);

        // s2 decodes first: its append must not touch s1's shared tail
        assert!(e.step_session(&mut s2).unwrap());
        assert_eq!(e.paged_snapshot().unwrap().stats.cow_copies, 1, "CoW on divergence");
        // s1 now owns its tail alone: appending unpublishes, no CoW
        assert!(e.step_session(&mut s1).unwrap());
        assert_eq!(e.paged_snapshot().unwrap().stats.cow_copies, 1, "sole owner appends in place");

        while e.step_session(&mut s1).unwrap() {}
        while e.step_session(&mut s2).unwrap() {}
        e.finish_session(s1);
        e.finish_session(s2);
        let snap = e.paged_snapshot().unwrap();
        assert_eq!(snap.live_tables, 0, "[{}] sessions released", e.backend_name());
        assert_eq!(snap.used_bytes, snap.cached_bytes, "only evictable cache remains");
        assert_eq!(snap.stats.alloc_failures, 0);
    }
}

#[test]
fn coordinator_shares_prefix_blocks_across_requests() {
    for base in stack_cfgs() {
        let cfg = ServingConfig { max_batch: 4, kv_block_size: 16, ..base };
        assert!(cfg.paged_kv, "paged serving must be the default");
        let backend = cfg.backend.clone();
        let handle = Coordinator::start(cfg).unwrap();
        let coord = handle.coordinator.clone();

        // three requests with the same prompt: whichever prefills first
        // publishes its prompt blocks, and the followers adopt at least
        // the full block regardless of tick interleaving (the
        // deterministic CoW assertions live in the engine-level test)
        let prompt = "the color of tom is";
        let rxs: Vec<_> = (0..3).map(|_| coord.submit(prompt, 6, Variant::Chai)).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert!(resp.n_generated >= 1);
        }

        // gauges are published at the end of the tick that retires the
        // last session — responses are sent slightly earlier in the same
        // tick, so poll briefly instead of racing the engine loop
        let m = &coord.metrics;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while (m.gauge("kv_capacity_bytes") == 0.0 || m.gauge("kv_live_tables") != 0.0)
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(
            m.gauge("paged_prefix_hit_blocks") >= 1.0,
            "[{backend}] no prefix blocks adopted: hit={} miss={}",
            m.gauge("paged_prefix_hit_blocks"),
            m.gauge("paged_prefix_miss_blocks"),
        );
        assert!(m.gauge("paged_prefix_hit_rate") > 0.0);
        // all sessions finished: every block went back to the pool (what
        // remains is evictable prefix cache, not leaked live state)
        assert_eq!(m.gauge("kv_live_tables"), 0.0);
        assert_eq!(m.gauge("kv_used_bytes"), m.gauge("kv_cached_bytes"));
        assert!(m.gauge("kv_used_bytes") <= m.gauge("kv_capacity_bytes"));
        assert_eq!(m.gauge("paged_alloc_failures"), 0.0);
        handle.shutdown();
    }
}

#[test]
fn coordinator_legacy_path_still_works() {
    for base in stack_cfgs() {
        let cfg = ServingConfig { max_batch: 2, paged_kv: false, ..base };
        let handle = Coordinator::start(cfg).unwrap();
        let coord = handle.coordinator.clone();
        let rx = coord.submit("the color of tom is", 4, Variant::Chai);
        let resp = rx.recv_timeout(std::time::Duration::from_secs(600)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(coord.metrics.gauge("kv_used_bytes"), 0.0, "no paged gauges on legacy path");
        handle.shutdown();
    }
}
