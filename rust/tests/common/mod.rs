//! Shared config plumbing for the integration suites: which backends
//! to drive the full stack with.

use std::path::{Path, PathBuf};

use chai::config::ServingConfig;

/// The AOT artifacts dir, when `make artifacts` has produced one.
#[allow(dead_code)] // each test binary compiles its own copy of this module
pub fn artifacts() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

/// Configs to drive the full stack with: the reference backend always
/// (toy model when artifacts are absent, real weights when present),
/// plus the XLA backend when artifacts exist.
#[allow(dead_code)] // each test binary compiles its own copy of this module
pub fn stack_cfgs() -> Vec<ServingConfig> {
    let mut cfgs = vec![ServingConfig {
        artifacts_dir: artifacts().unwrap_or_else(|| PathBuf::from("no-artifacts")),
        backend: "ref".into(),
        ..Default::default()
    }];
    if let Some(dir) = artifacts() {
        cfgs.push(ServingConfig { artifacts_dir: dir, backend: "xla".into(), ..Default::default() });
    }
    cfgs
}
