//! End-to-end serving driver (the repro mandate's E2E example): start the
//! coordinator + TCP server, replay a Poisson trace of batched requests
//! through real sockets, and report latency/throughput for CHAI vs MHA.
//!
//! Run:  cargo run --release --example serve_trace -- \
//!           [--requests 24] [--rate 4] [--max-new 12] [--variant chai,mha]

use std::sync::{Arc, Mutex};

use anyhow::Result;
use chai::bench::{poisson_trace, Table};
use chai::config::ServingConfig;
use chai::coordinator::Coordinator;
use chai::server::{Client, Server};
use chai::util::args::Args;
use chai::util::now_ms;
use chai::util::stats::{mean, percentile};

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let n = args.usize("requests", 24)?;
    let rate = args.f64("rate", 4.0)?;
    let max_new = args.usize("max-new", 12)?;
    let variants = args.str("variant", "chai,mha");

    let mut table = Table::new(
        "E2E serving: Poisson trace over TCP (per variant)",
        &["variant", "req", "ok", "mean ttft ms", "p95 ttft", "mean e2e ms", "p95 e2e", "tok/s"],
    );

    for variant in variants.split(',') {
        let cfg = ServingConfig { artifacts_dir: dir.clone(), max_batch: 8, ..Default::default() };
        let handle = Coordinator::start(cfg)?;
        let server = Server::start(handle.coordinator.clone(), "127.0.0.1:0")?;
        let addr = server.addr.to_string();

        // warm the executables so the trace measures steady-state
        {
            let mut c = Client::connect(&addr)?;
            c.generate("the color of tom is", 2, variant)?;
        }

        let trace = poisson_trace(n, rate, max_new.saturating_sub(4).max(1), max_new, 42);
        let t0 = now_ms();
        let results: Arc<Mutex<Vec<(f64, f64, usize, bool)>>> = Arc::new(Mutex::new(Vec::new()));
        let mut joins = Vec::new();
        for req in trace {
            let addr = addr.clone();
            let variant = variant.to_string();
            let results = results.clone();
            joins.push(std::thread::spawn(move || {
                let wait = req.arrival_ms - (now_ms() - t0);
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_millis(wait as u64));
                }
                let mut c = Client::connect(&addr).expect("connect");
                let sent = now_ms();
                let resp = c.generate(&req.prompt, req.max_new, &variant).expect("generate");
                let e2e = now_ms() - sent;
                let ok = resp.opt("error").is_none();
                let ttft = resp.opt("ttft_ms").map(|v| v.num().unwrap()).unwrap_or(0.0);
                let ntok = resp
                    .opt("n_generated")
                    .map(|v| v.usize().unwrap())
                    .unwrap_or(0);
                results.lock().unwrap().push((ttft, e2e, ntok, ok));
            }));
        }
        for j in joins {
            let _ = j.join();
        }
        let span_s = (now_ms() - t0) / 1e3;
        let res = results.lock().unwrap();
        let ttfts: Vec<f64> = res.iter().filter(|r| r.3).map(|r| r.0).collect();
        let e2es: Vec<f64> = res.iter().filter(|r| r.3).map(|r| r.1).collect();
        let total_tokens: usize = res.iter().filter(|r| r.3).map(|r| r.2).sum();
        let ok = res.iter().filter(|r| r.3).count();
        table.row(vec![
            variant.to_string(),
            n.to_string(),
            ok.to_string(),
            format!("{:.1}", mean(&ttfts)),
            format!("{:.1}", percentile(&ttfts, 95.0)),
            format!("{:.1}", mean(&e2es)),
            format!("{:.1}", percentile(&e2es, 95.0)),
            format!("{:.1}", total_tokens as f64 / span_s),
        ]);
        server.stop();
        handle.shutdown();
    }
    table.print();
    println!("\nshape check: CHAI ttft/e2e should sit at or below MHA at equal load");
    println!("(single-core CPU testbed; paper runs 8xV100 — ratios, not absolutes)");
    Ok(())
}
