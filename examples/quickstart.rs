//! Quickstart: load the AOT artifacts, generate with MHA and CHAI, and
//! print the phase timing decomposition the paper's Figure 12 is built on.
//!
//! Run:  cargo run --release --example quickstart [-- --artifacts DIR]

use anyhow::Result;
use chai::engine::{Engine, Variant};
use chai::util::args::Args;
use chai::util::stats::mean;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let engine = Engine::from_dir(&dir)?;
    let m = engine.manifest();
    println!(
        "loaded {} ({} params, {} AOT artifacts, attn impl = {})",
        m.model.name,
        m.model.n_params,
        m.artifacts.len(),
        m.attn_impl
    );
    println!("offline k_list (elbow): {:?}  -> K-cache saving {:.1}%\n",
        m.k_list, 100.0 * chai::kv::chai_saving_fraction(m));

    let prompts = [
        "the color of tom is",
        "ana keeps the",
        "question : does leo eat",
    ];
    for variant in [Variant::Mha, Variant::Chai] {
        println!("--- variant: {} ---", variant.name());
        for p in &prompts {
            let g = engine.generate(p, 16, &variant)?;
            println!(
                "  {p:?} -> {:?}  (ttft {:.1} ms = probe {:.1} + cluster {:.2} + prefill {:.1}; \
                 decode {:.1} ms/tok)",
                g.text.trim(),
                g.timing.ttft_ms,
                g.timing.probe_ms,
                g.timing.cluster_ms,
                g.timing.prefill_ms,
                mean(&g.timing.decode_ms)
            );
        }
    }
    println!("\n(first generation per variant includes one-time XLA compilation;");
    println!(" the latency benches warm up executables before measuring)");
    Ok(())
}
