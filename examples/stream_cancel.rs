//! Streaming + cancellation client against the multi-replica router.
//!
//! Spins up a 2-replica router front-end (pure-rust reference backend —
//! no artifacts needed) behind the TCP line-JSON server, then:
//!
//!   1. streams a generation, printing each `{"id","i","tok","text"}`
//!      frame as it arrives, followed by the terminal summary line;
//!   2. starts a second streaming generation and cancels it mid-decode
//!      from ANOTHER connection (`{"cmd":"cancel","id":N}` — request
//!      ids are global across the front-end), showing the terminal
//!      `{"cancelled":true}` line and the clean pool afterwards.
//!
//! Run:  cargo run --release --example stream_cancel
//!       cargo run --release --example stream_cancel -- --replicas 4 --route prefix

use anyhow::Result;
use chai::config::ServingConfig;
use chai::engine::Variant;
use chai::router::{Frontend, Router};
use chai::scheduler::SubmitOpts;
use chai::server::{Client, Server};
use chai::util::args::Args;
use chai::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = ServingConfig {
        artifacts_dir: std::path::PathBuf::from(args.str("artifacts", "artifacts")),
        backend: args.str("backend", "ref"),
        replicas: args.usize("replicas", 2)?,
        route: args.str("route", "rr"),
        ..Default::default()
    };
    let replicas = cfg.replicas;
    let handle = Router::start(cfg)?;
    let router = handle.router.clone();
    let server = Server::start(router.clone(), "127.0.0.1:0")?;
    let addr = server.addr.to_string();
    println!("router serving on {addr} ({replicas} replicas)");

    // --- 1: stream a generation frame by frame ------------------------
    let mut client = Client::connect(&addr)?;
    println!("\n--- streaming generation ---");
    let done = client.generate_stream("the color of tom is", 12, "chai", |f| {
        println!(
            "frame {}: tok {:>3}  {:?}",
            f.get("i").unwrap().usize().unwrap(),
            f.get("tok").unwrap().usize().unwrap(),
            f.get("text").unwrap().str().unwrap(),
        );
    })?;
    println!("terminal: {}", done.to_string());
    anyhow::ensure!(done.opt("error").is_none(), "streaming failed: {}", done.to_string());

    // --- 2: cancel a streaming generation mid-decode ------------------
    println!("\n--- cancellation ---");
    // hogs keep both replicas' decode batches busy so the victim is
    // still mid-decode when the cancel lands
    let hogs: Vec<_> = (0..6)
        .map(|i| {
            router
                .submit_opts(SubmitOpts::new(&format!("hog {i}"), 56, Variant::Chai))
                .1
        })
        .collect();
    let mut victim = Client::connect(&addr)?;
    let mut side = Client::connect(&addr)?;
    victim.send(&Json::obj(vec![
        ("prompt", Json::Str("tom".into())),
        ("max_new", Json::Num(60.0)),
        ("stream", Json::Bool(true)),
    ]))?;
    let first = victim.read_json()?;
    let id = first.get("id")?.usize()? as u64;
    println!("victim request id {id}, first frame received — cancelling from another connection");
    let ack = side.cancel(id)?;
    println!("cancel ack: {}", ack.to_string());
    let terminal = loop {
        let j = victim.read_json()?;
        if j.opt("tok").is_none() {
            break j;
        }
    };
    println!("victim terminal: {}", terminal.to_string());
    anyhow::ensure!(
        terminal.opt("cancelled").is_some(),
        "expected a terminal cancelled line, got {}",
        terminal.to_string()
    );
    for rx in hogs {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "hog failed: {:?}", r.error);
    }

    // pool state after the abort: no live tables anywhere
    let kv = side.kv()?;
    println!("\npool after cancel: {}", kv.to_string());

    server.stop();
    handle.shutdown();
    println!("\nok: streamed, cancelled, and shut down cleanly");
    Ok(())
}
