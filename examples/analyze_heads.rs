//! Offline head-redundancy analysis — the example behind paper Figures
//! 2, 6, 7: per-layer correlation statistics, one sample's pairwise
//! correlation matrix, and the elbow read per layer.
//!
//! Run:  cargo run --release --example analyze_heads -- [--samples 32]

use anyhow::Result;
use chai::bench::Table;
use chai::clustering::{correlation, elbow};
use chai::engine::Engine;
use chai::model::tokenizer;
use chai::runtime::{Backend, In};
use chai::tensor::Tensor;
use chai::util::args::Args;
use chai::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let n_samples = args.usize("samples", 32)?;
    let engine = Engine::from_dir(&dir)?;
    let m = engine.manifest().clone();
    let (l, h, t) = (m.model.n_layers, m.model.n_heads, m.analyze_bucket);

    let samples: Vec<String> = Json::parse_file(&dir.join("analysis_samples.json"))?
        .get("samples")?
        .str_vec()?
        .into_iter()
        .take(n_samples)
        .collect();
    println!("collecting attention maps over {} held-out samples...", samples.len());

    let mut feats: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); h]; l];
    let mut single_sample_corr: Option<Vec<Vec<f32>>> = None;
    for (si, s) in samples.iter().enumerate() {
        let mut ids = tokenizer::encode(s, true, false);
        ids.truncate(t);
        let ln = ids.len();
        ids.resize(t, tokenizer::PAD);
        let outs = engine.rt.run(
            "analyze",
            &[In::Host(&Tensor::i32(vec![t], ids)), In::Host(&Tensor::scalar_i32(ln as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        let v = maps.as_f32()?;
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * h + hi) * t + (ln - 1)) * t;
                feats[li][hi].extend_from_slice(&v[base..base + ln]);
            }
        }
        if si == 0 {
            // Figure 2b / Figure 7: single-sample pairwise correlation of
            // the deepest layer's last-query attention.
            let layer: Vec<Vec<f32>> = (0..h)
                .map(|hi| {
                    let base = (((l - 1) * h + hi) * t + (ln - 1)) * t;
                    v[base..base + ln].to_vec()
                })
                .collect();
            single_sample_corr = Some(correlation::correlation_matrix(&layer));
        }
    }

    // Figure 6 analogue: per-layer mean correlation across samples.
    let mut fig6 = Table::new(
        "Figure 6 analogue: per-layer redundancy over held-out samples",
        &["layer", "mean corr", "frac>0.95", "frac>0.5", "elbow k", "offline k_list"],
    );
    for li in 0..l {
        let corr = correlation::correlation_matrix(&feats[li]);
        let res = elbow::cluster_layer(&feats[li], 0);
        fig6.row(vec![
            li.to_string(),
            format!("{:.3}", correlation::mean_offdiag(&corr)),
            format!("{:.2}", correlation::frac_above(&corr, 0.95)),
            format!("{:.2}", correlation::frac_above(&corr, 0.5)),
            res.k.to_string(),
            m.k_list[li].to_string(),
        ]);
    }
    fig6.print();

    // Figure 2b / 7: print the single-sample correlation matrix heatmap.
    if let Some(corr) = single_sample_corr {
        println!("\nFigure 2b/7 analogue: pairwise correlation, layer {} (one sample)", l - 1);
        print!("     ");
        for j in 0..h {
            print!("{j:>4}");
        }
        println!();
        for (i, row) in corr.iter().enumerate() {
            print!("h{i:<3} ");
            for c in row {
                // coarse heatmap: correlation in tenths
                print!("{:>4}", format!("{:.1}", c));
            }
            println!();
        }
    }

    println!("\npaper shape: correlation rises toward later layers; clusters of");
    println!("heads with corr > 0.95 exist there (the redundancy CHAI exploits).");
    Ok(())
}
