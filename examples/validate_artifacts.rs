//! Validate every artifact in the manifest: HLO text parses, compiles on
//! the PJRT CPU client, and executes with zero-filled inputs of the
//! manifest shapes. The smoke check to run after `make artifacts`.
//!
//! Run:  cargo run --release --example validate_artifacts [-- --artifacts DIR --execute]

use anyhow::Result;
use chai::config::Manifest;
use chai::runtime::{In, Runtime};
use chai::tensor::Tensor;
use chai::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let execute = args.bool("execute");
    let manifest = Manifest::load(&dir)?;
    let names: Vec<String> = manifest.artifacts.keys().cloned().collect();

    let mut ok = 0;
    let mut failed = 0;
    for name in &names {
        let spec = manifest.artifact(name)?;
        let path = manifest.hlo_path(spec);
        match xla::HloModuleProto::from_text_file(path.to_str().unwrap()) {
            Ok(_) => {}
            Err(e) => {
                println!("PARSE FAIL {name}: {e}");
                failed += 1;
                continue;
            }
        }
        if !execute {
            println!("parse ok   {name}");
            ok += 1;
            continue;
        }
        // full load + execute with zero inputs
        let rt = Runtime::load(&dir)?;
        let tensors: Vec<Tensor> = spec
            .inputs
            .iter()
            .map(|i| match i.dtype.as_str() {
                "int32" => Tensor::zeros_i32(&i.shape),
                _ => Tensor::zeros_f32(&i.shape),
            })
            .collect();
        let ins: Vec<In> = tensors.iter().map(In::Host).collect();
        match rt.run(name, &ins) {
            Ok(outs) => {
                println!("exec ok    {name} ({} outputs)", outs.len());
                ok += 1;
            }
            Err(e) => {
                println!("EXEC FAIL  {name}: {e:#}");
                failed += 1;
            }
        }
    }
    println!("\n{ok} ok, {failed} failed of {}", names.len());
    if failed > 0 {
        std::process::exit(1);
    }
    Ok(())
}
