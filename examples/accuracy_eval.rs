//! Accuracy evaluation across attention variants on the five synthetic
//! suites — the interactive version of Tables 1-3 (the full sweep is
//! `cargo bench --bench bench_accuracy_tables`).
//!
//! Run:  cargo run --release --example accuracy_eval -- \
//!           [--variants mha,chai,chai-static,dejavu-50] [--max-items 16]

use anyhow::Result;
use chai::bench::Table;
use chai::engine::{Engine, Variant};
use chai::eval;
use chai::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let engine = Engine::from_dir(&dir)?;
    let variants: Vec<Variant> = args
        .str("variants", "mha,chai,chai-static,dejavu-50,spatten")
        .split(',')
        .map(Variant::parse)
        .collect::<Result<_>>()?;
    let max_items = match args.usize("max-items", 16)? {
        0 => None,
        n => Some(n),
    };

    let mut table = Table::new(
        &format!("Accuracy on {} ({} items/suite)", engine.manifest().model.name,
                 max_items.map(|n| n.to_string()).unwrap_or_else(|| "all".into())),
        &["variant", "piqa", "hellaswag", "arc-c", "arc-e", "boolq", "mean"],
    );
    let mut mha_mean = None;
    for v in &variants {
        let mut row = vec![v.name()];
        let mut accs = Vec::new();
        for s in eval::SUITES {
            let suite = eval::load_suite(&dir, s)?;
            let acc = eval::accuracy(&engine, &suite, v, max_items)?;
            accs.push(acc);
            row.push(format!("{acc:.1}"));
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        row.push(format!("{mean:.1}"));
        if *v == Variant::Mha {
            mha_mean = Some(mean);
        }
        table.row(row);
    }
    table.print();
    if let Some(m) = mha_mean {
        println!("\npaper shape: CHAI within a few points of MHA ({m:.1} here);");
        println!("DejaVu-50% and SpAtten degrade hard on LLaMA-like models (Table 2).");
    }
    Ok(())
}
